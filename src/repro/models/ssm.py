"""Mamba-1 selective-state-space model (falcon-mamba-7b).

Attention-free: each block is  in_proj → causal depthwise conv → selective
scan (input-dependent Δ, B, C; diagonal A) → gate → out_proj.

TPU mapping of the recurrence: a *chunked* scan — ``lax.scan`` over sequence
chunks carrying the (B, d_inner, n) state, with a parallel
``lax.associative_scan`` inside each chunk.  This bounds activation memory to
O(chunk · d_inner · n) while exposing intra-chunk parallelism to the VPU,
the standard TPU-native formulation (vs. the CUDA kernel's warp-level scan,
which has no TPU analogue — see DESIGN.md hardware-adaptation notes).

Decode carries an O(1) recurrent state per layer: the conv tail (conv_width
inputs) and the SSM state (d_inner × n) — this is why falcon-mamba runs the
long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat

from repro.models import layers as L
from repro.models.base import ModelConfig
from repro.parallel.sharding import shard

SCAN_CHUNK = 256


def layer_shapes(cfg: ModelConfig, dtype) -> dict:
    d, di, n, r = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    return {
        "norm": L.vec(d, dtype),
        "in_proj": L.dense(d, 2 * di, dtype),
        "conv_w": jax.ShapeDtypeStruct((di, cfg.ssm_conv), dtype),
        "conv_b": L.vec(di, dtype),
        "x_proj": L.dense(di, r + 2 * n, dtype),
        "dt_proj": L.dense(r, di, dtype),
        "dt_proj_b": L.vec(di, dtype),
        "A_log": jax.ShapeDtypeStruct((di, n), dtype),
        "D": L.vec(di, dtype),
        "out_proj": L.dense(di, d, dtype),
    }


def param_shapes(cfg: ModelConfig, dtype=jnp.float32) -> dict:
    stacked = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((cfg.n_layers,) + s.shape, s.dtype),
        layer_shapes(cfg, dtype),
    )
    return {
        "embed": jax.ShapeDtypeStruct((cfg.vocab, cfg.d_model), dtype),
        "final_norm": L.vec(cfg.d_model, dtype),
        "layers": stacked,
        "lm_head": L.dense(cfg.d_model, cfg.vocab, dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray):
    """Depthwise causal conv: x (B, S, di), w (di, K) → (B, S, di)."""
    k = w.shape[1]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    y = sum(xp[:, j: j + x.shape[1]] * w[:, j].astype(x.dtype) for j in range(k))
    return y + b.astype(x.dtype)


def _ssm_inputs(cfg, lp, x1):
    """Input-dependent Δ (B,S,di), B̄ (B,S,n), C (B,S,n), A (di,n)."""
    n, r = cfg.ssm_state, cfg.dt_rank
    x_dbl = x1 @ lp["x_proj"].astype(x1.dtype)
    dt, b_in, c_in = jnp.split(x_dbl, [r, r + n], axis=-1)
    delta = jax.nn.softplus(
        (dt @ lp["dt_proj"].astype(dt.dtype)).astype(jnp.float32)
        + lp["dt_proj_b"].astype(jnp.float32))
    a = -jnp.exp(lp["A_log"].astype(jnp.float32))           # (di, n)
    return delta, b_in.astype(jnp.float32), c_in.astype(jnp.float32), a


def _chunked_selective_scan(delta, b_in, c_in, a, x1, h0):
    """h_t = exp(Δ_t·A)·h_{t-1} + Δ_t·B_t·x_t ;  y_t = C_t·h_t.

    delta (B,S,di) fp32, b_in/c_in (B,S,n), a (di,n), x1 (B,S,di),
    h0 (B,di,n) fp32 → y (B,S,di) fp32, h_final."""
    bsz, s, di = delta.shape
    n = b_in.shape[-1]
    chunk = min(SCAN_CHUNK, s)
    pad = (-s) % chunk
    if pad:
        delta = jnp.pad(delta, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
        x1 = jnp.pad(x1, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // chunk

    def reshape_c(t):
        return jnp.moveaxis(t.reshape(bsz, nc, chunk, *t.shape[2:]), 1, 0)

    dl, bb, cc, xx = map(reshape_c, (delta, b_in, c_in, x1))

    def chunk_step(h, inputs):
        d_c, b_c, c_c, x_c = inputs                       # (B, ch, …)
        da = jnp.exp(d_c[..., None] * a)                  # (B, ch, di, n)
        dbx = (d_c * x_c.astype(jnp.float32))[..., None] * b_c[:, :, None, :]

        def combine(l, r_):
            al, bl = l
            ar, br = r_
            return al * ar, br + ar * bl

        a_cum, b_cum = jax.lax.associative_scan(combine, (da, dbx), axis=1)
        h_t = a_cum * h[:, None] + b_cum                  # (B, ch, di, n)
        y_c = jnp.einsum("bcdn,bcn->bcd", h_t, c_c)
        return h_t[:, -1], y_c

    h_f, ys = jax.lax.scan(chunk_step, h0, (dl, bb, cc, xx))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s + pad, di)[:, :s]
    return y, h_f


def block(cfg: ModelConfig, lp, x, h0=None):
    """Full-sequence mamba block.  Returns (x_out, h_final)."""
    bsz, s, _ = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    h = L.rms_norm(x, lp["norm"], cfg.norm_eps)
    xz = h @ lp["in_proj"].astype(h.dtype)
    x1, z = jnp.split(xz, 2, axis=-1)
    x1 = shard(x1, "batch", None, "tp")
    x1 = jax.nn.silu(_causal_conv(x1, lp["conv_w"], lp["conv_b"]))
    delta, b_in, c_in, a = _ssm_inputs(cfg, lp, x1)
    if h0 is None:
        h0 = jnp.zeros((bsz, di, n), jnp.float32)
    y, h_f = _chunked_selective_scan(delta, b_in, c_in, a, x1, h0)
    y = y + lp["D"].astype(jnp.float32) * x1.astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ lp["out_proj"].astype(x.dtype)
    return x + out, h_f


def forward(cfg: ModelConfig, params, batch, *, return_cache: bool = False,
            return_hidden: bool = False):
    tokens = batch["tokens"]
    x = L.embed_lookup(params["embed"].astype(L.COMPUTE_DTYPE), tokens)
    x = shard(x, "batch", "seq", None)

    def body(x, lp):
        # pin the scan carry against convert hoisting (see transformer)
        x = compat.opt_barrier(x)
        x, h_f = block(cfg, lp, x)
        return shard(x, "batch", "seq", None), h_f

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        x, h_stack = L.segmented_scan(body, x, params["layers"],
                                      cfg.n_layers)
    else:
        hs = []
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            x, h_f = body(x, lp)
            hs.append(h_f)
        h_stack = jnp.stack(hs)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x
    logits = x @ params["lm_head"].astype(x.dtype)
    logits = shard(logits, "batch", None, "tp")
    if return_cache:
        return logits, h_stack
    return logits


def decode_state_shapes(cfg: ModelConfig, batch_size: int, seq_len: int,
                        dtype=jnp.bfloat16) -> dict:
    del seq_len  # O(1) state — the whole point of the SSM long_500k cell
    return {
        "conv": jax.ShapeDtypeStruct(
            (cfg.n_layers, batch_size, cfg.ssm_conv, cfg.d_inner), dtype),
        "h": jax.ShapeDtypeStruct(
            (cfg.n_layers, batch_size, cfg.d_inner, cfg.ssm_state),
            jnp.float32),
    }


def decode_step(cfg: ModelConfig, params, state, batch):
    x = params["embed"].astype(L.COMPUTE_DTYPE)[batch["tokens"]]  # (B, 1, d)

    def step(x, per_layer):
        lp, conv_st, h_st = per_layer
        hin = L.rms_norm(x, lp["norm"], cfg.norm_eps)
        xz = hin @ lp["in_proj"].astype(hin.dtype)
        x1, z = jnp.split(xz, 2, axis=-1)                  # (B, 1, di)
        conv_st = jnp.concatenate(
            [conv_st[:, 1:], x1.astype(conv_st.dtype)], axis=1)
        w = lp["conv_w"].astype(jnp.float32)               # (di, K)
        x1c = jnp.einsum("bkd,dk->bd", conv_st.astype(jnp.float32), w)
        x1c = jax.nn.silu(x1c + lp["conv_b"].astype(jnp.float32))
        x1c = x1c[:, None].astype(x.dtype)                 # (B, 1, di)
        delta, b_in, c_in, a = _ssm_inputs(cfg, lp, x1c)
        da = jnp.exp(delta[:, 0, :, None] * a)             # (B, di, n)
        dbx = (delta[:, 0] * x1c[:, 0].astype(jnp.float32))[..., None] \
            * b_in[:, 0, None, :]
        h_new = da * h_st + dbx
        y = jnp.einsum("bdn,bn->bd", h_new, c_in[:, 0])
        y = y + lp["D"].astype(jnp.float32) * x1c[:, 0].astype(jnp.float32)
        y = y[:, None].astype(x.dtype) * jax.nn.silu(z)
        x = x + y @ lp["out_proj"].astype(x.dtype)
        return x, (conv_st, h_new)

    if cfg.scan_layers:
        x, (conv_new, h_new) = jax.lax.scan(
            step, x, (params["layers"], state["conv"], state["h"]))
    else:
        cs, hs = [], []
        for i in range(cfg.n_layers):
            per = jax.tree_util.tree_map(
                lambda a: a[i],
                (params["layers"], state["conv"], state["h"]))
            x, (c_, h_) = step(x, per)
            cs.append(c_)
            hs.append(h_)
        conv_new, h_new = jnp.stack(cs), jnp.stack(hs)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"].astype(x.dtype)
    return logits, {"conv": conv_new, "h": h_new}
