"""Whisper-medium backbone: encoder-decoder transformer.

Per the assignment the conv frontend is a STUB — ``input_specs()`` provides
precomputed frame embeddings (B, enc_frames, d_model).  Positions are
sinusoidal on both stacks (deviation from Whisper's learned decoder table,
which tops out at 448 positions — the assigned decode_32k cell needs
unbounded positions; noted in DESIGN.md).  Norms are LayerNorm (scale
stored as 1+w so zero-init is identity), MLPs are plain GeLU (non-gated),
attention is full MHA (n_kv_heads == n_heads).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat

from repro.models import layers as L
from repro.models import transformer as T
from repro.models.base import ModelConfig
from repro.parallel.sharding import shard


def _ln(x, lp, name, eps):
    return L.layer_norm(x, 1.0 + lp[f"{name}_scale"], lp[f"{name}_bias"], eps)


def _attn_shapes(cfg, dtype, prefix):
    d, hd = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.n_heads * hd, cfg.n_kv_heads * hd
    return {
        f"{prefix}wq": L.dense(d, hq, dtype),
        f"{prefix}wk": L.dense(d, hkv, dtype),
        f"{prefix}wv": L.dense(d, hkv, dtype),
        f"{prefix}wo": L.dense(hq, d, dtype),
        f"{prefix}wq_b": L.vec(hq, dtype),
        f"{prefix}wv_b": L.vec(hkv, dtype),
        f"{prefix}wo_b": L.vec(d, dtype),
    }


def enc_layer_shapes(cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    p = {"attn_norm_scale": L.vec(d, dtype), "attn_norm_bias": L.vec(d, dtype),
         "mlp_norm_scale": L.vec(d, dtype), "mlp_norm_bias": L.vec(d, dtype),
         "w_up": L.dense(d, cfg.d_ff, dtype), "w_up_b": L.vec(cfg.d_ff, dtype),
         "w_down": L.dense(cfg.d_ff, d, dtype), "w_down_b": L.vec(d, dtype)}
    p.update(_attn_shapes(cfg, dtype, ""))
    return p


def dec_layer_shapes(cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    p = enc_layer_shapes(cfg, dtype)
    p.update(_attn_shapes(cfg, dtype, "x_"))
    p["x_norm_scale"] = L.vec(d, dtype)
    p["x_norm_bias"] = L.vec(d, dtype)
    return p


def param_shapes(cfg: ModelConfig, dtype=jnp.float32) -> dict:
    stack = lambda s, n: jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct((n,) + x.shape, x.dtype), s)
    return {
        "embed": jax.ShapeDtypeStruct((cfg.vocab, cfg.d_model), dtype),
        "enc_layers": stack(enc_layer_shapes(cfg, dtype), cfg.enc_layers),
        "dec_layers": stack(dec_layer_shapes(cfg, dtype), cfg.n_layers),
        "enc_norm_scale": L.vec(cfg.d_model, dtype),
        "enc_norm_bias": L.vec(cfg.d_model, dtype),
        "dec_norm_scale": L.vec(cfg.d_model, dtype),
        "dec_norm_bias": L.vec(cfg.d_model, dtype),
    }


def _sinusoid(positions, d):
    half = d // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                   / max(half - 1, 1))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _mha(cfg, lp, q_in, kv_in, prefix, *, causal, kv_valid=None, q_offset=0):
    b, s, _ = q_in.shape
    hd = cfg.head_dim
    q = (q_in @ lp[f"{prefix}wq"].astype(q_in.dtype)
         + lp[f"{prefix}wq_b"].astype(q_in.dtype))
    k = kv_in @ lp[f"{prefix}wk"].astype(kv_in.dtype)
    v = (kv_in @ lp[f"{prefix}wv"].astype(kv_in.dtype)
         + lp[f"{prefix}wv_b"].astype(kv_in.dtype))
    t = kv_in.shape[1]
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, t, cfg.n_kv_heads, hd)
    v = v.reshape(b, t, cfg.n_kv_heads, hd)
    o = L.gqa_attention(q, k, v, causal=causal, kv_valid=kv_valid,
                        q_offset=q_offset)
    o = o.reshape(b, s, cfg.n_heads * hd)
    return (o @ lp[f"{prefix}wo"].astype(o.dtype)
            + lp[f"{prefix}wo_b"].astype(o.dtype)), (k, v)


def _mlp(cfg, lp, x):
    h = jax.nn.gelu(x @ lp["w_up"].astype(x.dtype)
                    + lp["w_up_b"].astype(x.dtype))
    h = shard(h, "batch", "seq", None)
    return h @ lp["w_down"].astype(x.dtype) + lp["w_down_b"].astype(x.dtype)


def encode(cfg: ModelConfig, params, frames: jnp.ndarray) -> jnp.ndarray:
    """frames (B, F, d) — stubbed frontend output — → encoder states."""
    b, f, d = frames.shape
    pos = jnp.broadcast_to(jnp.arange(f)[None], (b, f))
    x = frames.astype(L.COMPUTE_DTYPE) + _sinusoid(pos, d).astype(L.COMPUTE_DTYPE)
    x = shard(x, "batch", "seq", None)

    def body(x, lp):
        # pin the scan carry against convert hoisting (see transformer)
        x = compat.opt_barrier(x)
        h = _ln(x, lp, "attn_norm", cfg.norm_eps)
        o, _ = _mha(cfg, lp, h, h, "", causal=False)
        x = x + o
        h = _ln(x, lp, "mlp_norm", cfg.norm_eps)
        x = x + _mlp(cfg, lp, h)
        return shard(x, "batch", "seq", None), None

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
    else:
        for i in range(cfg.enc_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["enc_layers"])
            x, _ = body(x, lp)
    return L.layer_norm(x, 1.0 + params["enc_norm_scale"],
                        params["enc_norm_bias"], cfg.norm_eps)


def forward(cfg: ModelConfig, params, batch, *, return_cache: bool = False,
            return_hidden: bool = False):
    """batch: tokens (B, S) decoder input, frames (B, F, d)."""
    enc = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    b, s = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x = L.embed_lookup(params["embed"].astype(L.COMPUTE_DTYPE), tokens)
    x = x + _sinusoid(pos, cfg.d_model).astype(x.dtype)
    x = shard(x, "batch", "seq", None)

    def body(x, lp):
        # pin the scan carry against convert hoisting (see transformer)
        x = compat.opt_barrier(x)
        h = _ln(x, lp, "attn_norm", cfg.norm_eps)
        o, kv = _mha(cfg, lp, h, h, "", causal=True)
        x = x + o
        h = _ln(x, lp, "x_norm", cfg.norm_eps)
        o, xkv = _mha(cfg, lp, h, enc, "x_", causal=False)
        x = x + o
        h = _ln(x, lp, "mlp_norm", cfg.norm_eps)
        x = x + _mlp(cfg, lp, h)
        return shard(x, "batch", "seq", None), (kv, xkv)

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        x, caches = jax.lax.scan(body, x, params["dec_layers"])
    else:
        caches = None
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["dec_layers"])
            x, _ = body(x, lp)
    x = L.layer_norm(x, 1.0 + params["dec_norm_scale"],
                     params["dec_norm_bias"], cfg.norm_eps)
    if return_hidden:
        return x
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    logits = shard(logits, "batch", None, "tp")
    if return_cache:
        return logits, caches
    return logits


def decode_state_shapes(cfg: ModelConfig, batch_size: int, seq_len: int,
                        dtype=jnp.bfloat16) -> dict:
    hd, hkv = cfg.head_dim, cfg.n_kv_heads
    return {
        "k": jax.ShapeDtypeStruct(
            (cfg.n_layers, batch_size, seq_len, hkv, hd), dtype),
        "v": jax.ShapeDtypeStruct(
            (cfg.n_layers, batch_size, seq_len, hkv, hd), dtype),
        # cross-attention K/V precomputed from encoder output at prefill
        "xk": jax.ShapeDtypeStruct(
            (cfg.n_layers, batch_size, cfg.enc_frames, hkv, hd), dtype),
        "xv": jax.ShapeDtypeStruct(
            (cfg.n_layers, batch_size, cfg.enc_frames, hkv, hd), dtype),
    }


def decode_step(cfg: ModelConfig, params, state, batch):
    pos = batch["pos"]
    tokens = batch["tokens"]
    b = tokens.shape[0]
    x = L.embed_lookup(params["embed"].astype(L.COMPUTE_DTYPE), tokens)
    p = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)
    x = x + _sinusoid(p, cfg.d_model).astype(x.dtype)
    hd = cfg.head_dim
    t = state["k"].shape[2]
    valid = jnp.broadcast_to(jnp.arange(t) <= pos, (b, t))

    def body(x, per_layer):
        lp, kc, vc, xk, xv = per_layer
        h = _ln(x, lp, "attn_norm", cfg.norm_eps)
        q = (h @ lp["wq"].astype(h.dtype) + lp["wq_b"].astype(h.dtype))
        k = h @ lp["wk"].astype(h.dtype)
        v = h @ lp["wv"].astype(h.dtype) + lp["wv_b"].astype(h.dtype)
        q = q.reshape(b, 1, cfg.n_heads, hd)
        k = k.reshape(b, 1, cfg.n_kv_heads, hd)
        v = v.reshape(b, 1, cfg.n_kv_heads, hd)
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype),
                                          (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype),
                                          (0, pos, 0, 0))
        o = L.gqa_attention(q, kc, vc, causal=False, kv_valid=valid)
        o = o.astype(x.dtype)
        x = x + (o.reshape(b, 1, -1) @ lp["wo"].astype(x.dtype)
                 + lp["wo_b"].astype(x.dtype))
        h = _ln(x, lp, "x_norm", cfg.norm_eps)
        qx = (h @ lp["x_wq"].astype(h.dtype) + lp["x_wq_b"].astype(h.dtype))
        qx = qx.reshape(b, 1, cfg.n_heads, hd)
        o = L.gqa_attention(qx, xk.astype(x.dtype), xv.astype(x.dtype),
                            causal=False)
        x = x + (o.reshape(b, 1, -1) @ lp["x_wo"].astype(x.dtype)
                 + lp["x_wo_b"].astype(x.dtype))
        h = _ln(x, lp, "mlp_norm", cfg.norm_eps)
        x = x + _mlp(cfg, lp, h)
        return x, (kc, vc)

    if cfg.scan_layers:
        x, (k_new, v_new) = jax.lax.scan(
            body, x, (params["dec_layers"], state["k"], state["v"],
                      state["xk"], state["xv"]))
    else:
        ks, vs = [], []
        for i in range(cfg.n_layers):
            per = jax.tree_util.tree_map(
                lambda a: a[i],
                (params["dec_layers"], state["k"], state["v"],
                 state["xk"], state["xv"]))
            x, (kc, vc) = body(x, per)
            ks.append(kc)
            vs.append(vc)
        k_new, v_new = jnp.stack(ks), jnp.stack(vs)
    x = L.layer_norm(x, 1.0 + params["dec_norm_scale"],
                     params["dec_norm_bias"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    return logits, {"k": k_new, "v": v_new, "xk": state["xk"],
                    "xv": state["xv"]}
