"""Unified model API: one entry point per lifecycle step, dispatched on
``cfg.family``.

    param_shapes(cfg, dtype)          → pytree of ShapeDtypeStruct
    init_params(cfg, key, dtype)      → pytree of arrays
    forward(cfg, params, batch)       → logits (train/prefill)
    loss_fn(cfg, params, batch)       → scalar causal-LM loss
    decode_state_shapes(cfg, B, S)    → pytree of ShapeDtypeStruct
    init_decode_state(cfg, B, S)      → zeroed state (ring buffers at -1)
    decode_step(cfg, params, st, b)   → (logits, state)
    count_params(cfg[, active_only])  → int (roofline 6·N·D arithmetic)
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro import compat
from repro.models import griffin, layers, moe, ssm, transformer, whisper
from repro.models.base import ModelConfig
from repro.parallel.sharding import shard

_FAMILIES = {
    "dense": transformer,
    "vlm": transformer,
    "moe": moe,
    "ssm": ssm,
    "hybrid": griffin,
    "encdec": whisper,
}


def module_for(cfg: ModelConfig):
    return _FAMILIES[cfg.family]


def param_shapes(cfg: ModelConfig, dtype=jnp.float32):
    return module_for(cfg).param_shapes(cfg, dtype)


def init_params(cfg: ModelConfig, key, dtype=jnp.float32):
    return layers.init_from_shapes(param_shapes(cfg, dtype), key)


def forward(cfg: ModelConfig, params, batch, **kw):
    return module_for(cfg).forward(cfg, params, batch, **kw)


LOSS_CHUNK = 256


def _head_logits(cfg: ModelConfig, params, h: jnp.ndarray) -> jnp.ndarray:
    """Apply the LM head to hidden states (tied or untied)."""
    head = params.get("lm_head") if isinstance(params, dict) else None
    if head is None:
        return jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(h.dtype))
    return h @ head.astype(h.dtype)


def _vocab_parallel_xent(cfg, params, hidden, targets, weights, mesh):
    """Megatron-style vocab-parallel cross-entropy (§Perf iteration D4).

    hidden stays (batch, seq)-sharded; the head stays vocab-sharded; every
    device computes partial logits (B_loc, S_loc, V/m) against its own vocab
    shard and the logsumexp / gold-logit terms combine with pmax/psum of
    (B_loc, S_loc) scalars.  No activation gather, no head gather — the
    chunked-scan loss was measured slicing a model-sharded hidden, which XLA
    'resolves' by replicating it (9× 2.15 GB f32 copies on qwen2-vl).
    Vocabs that do not divide the model axis (49155, 51865) are zero-padded;
    padding columns are masked to -inf."""
    from repro.parallel.sharding import excluded_axes
    m_sz = mesh.shape["model"]
    head = params.get("lm_head")
    tied = head is None
    if tied:
        head = params["embed"]            # (V, d)
    v = cfg.vocab
    vpad = -(-v // m_sz) * m_sz
    if vpad != v:
        pw = ((0, vpad - v), (0, 0)) if tied else ((0, 0), (0, vpad - v))
        head = jnp.pad(head, pw)
    vloc = vpad // m_sz
    dp_axes = tuple(a for a in ("pod", "data")
                    if a in mesh.axis_names and a not in excluded_axes())
    b, s = targets.shape
    seq_ok = s % m_sz == 0
    h_spec = jax.sharding.PartitionSpec(
        dp_axes or None, "model" if seq_ok else None, None)
    t_spec = jax.sharding.PartitionSpec(
        dp_axes or None, "model" if seq_ok else None)
    head_spec = (jax.sharding.PartitionSpec("model", None) if tied
                 else jax.sharding.PartitionSpec(None, "model"))

    def body(h, t, w, hd):
        hd16 = hd.astype(h.dtype)
        if tied:
            lg = jnp.einsum("bsd,vd->bsv", h, hd16,
                            preferred_element_type=jnp.float32)
        else:
            lg = jnp.einsum("bsd,dv->bsv", h, hd16,
                            preferred_element_type=jnp.float32)
        j = jax.lax.axis_index("model")
        vstart = j * vloc
        col = vstart + jnp.arange(vloc)
        lg = jnp.where(col[None, None, :] < v, lg, -1e30)
        # max is a gradient-neutral stabiliser; pmax has no differentiation
        # rule, so gather the (B,S) per-shard maxima (tiny) and reduce
        lmax = jax.lax.all_gather(
            jax.lax.stop_gradient(lg.max(-1)), "model").max(0)
        sumexp = jnp.exp(lg - lmax[..., None]).sum(-1)
        logz = lmax + jnp.log(jax.lax.psum(sumexp, "model"))
        in_range = (t >= vstart) & (t < vstart + vloc)
        t_loc = jnp.clip(t - vstart, 0, vloc - 1)
        gold_l = jnp.take_along_axis(lg, t_loc[..., None], axis=-1)[..., 0]
        gold = jax.lax.psum(jnp.where(in_range, gold_l, 0.0), "model")
        total = ((logz - gold) * w).sum()
        axes = ("model",) + dp_axes if seq_ok else dp_axes
        return jax.lax.psum(total, axes) if axes else total

    total = compat.shard_map(
        body, mesh=mesh,
        in_specs=(h_spec, t_spec, t_spec, head_spec),
        out_specs=jax.sharding.PartitionSpec(),
        axis_names={"model"} | set(dp_axes), check_vma=False,
    )(hidden, targets, weights.astype(jnp.float32), head)
    return total / weights.sum()


def loss_fn(cfg: ModelConfig, params, batch,
            chunk: int = LOSS_CHUNK) -> jnp.ndarray:
    """Next-token cross-entropy.

    With a live multi-device mesh: vocab-parallel shard_map cross-entropy
    (see :func:`_vocab_parallel_xent`).  Without one (smoke tests): a
    seq-chunked scan bounds the logits memory."""
    from repro.parallel.sharding import current_mesh, excluded_axes
    hidden = forward(cfg, params, batch, return_hidden=True)
    tokens = batch["tokens"]
    b, s = tokens.shape
    targets = jnp.roll(tokens, -1, axis=1)
    # final position has no next token — weight 0
    weights = jnp.ones((b, s), jnp.float32).at[:, -1].set(0.0)

    mesh = current_mesh()
    if (mesh is not None and "model" in mesh.axis_names
            and mesh.shape["model"] > 1
            and not excluded_axes()):  # nested shard_map can't re-enter a
        # partial-manual region (pipeline / compressed cross-pod modes)
        return _vocab_parallel_xent(cfg, params, hidden, targets, weights,
                                    mesh)

    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        weights = jnp.pad(weights, ((0, 0), (0, pad)))
    nc = (s + pad) // c
    hc = jnp.moveaxis(hidden.reshape(b, nc, c, -1), 1, 0)
    tc = jnp.moveaxis(targets.reshape(b, nc, c), 1, 0)
    wc = jnp.moveaxis(weights.reshape(b, nc, c), 1, 0)

    def body(acc, inp):
        h, t, w = inp
        lg = _head_logits(cfg, params, h).astype(jnp.float32)
        # vocab stays sharded over 'tp' — the reductions psum (B,c) scalars
        lg = shard(lg, "batch", None, "tp")
        logz = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, t[..., None], axis=-1)[..., 0]
        return acc + ((logz - gold) * w).sum(), None

    # recompute each chunk's logits in the backward pass — saving them would
    # stack the full (B, S, vocab) fp32 logits the chunking exists to avoid
    body = jax.checkpoint(body,
                          policy=jax.checkpoint_policies.nothing_saveable)
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, tc, wc))
    return total / weights.sum()


def decode_state_shapes(cfg: ModelConfig, batch_size: int, seq_len: int,
                        dtype=jnp.bfloat16):
    return module_for(cfg).decode_state_shapes(cfg, batch_size, seq_len, dtype)


def init_decode_state(cfg: ModelConfig, batch_size: int, seq_len: int,
                      dtype=jnp.bfloat16):
    shapes = decode_state_shapes(cfg, batch_size, seq_len, dtype)

    def init(path, s):
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        if "slot_pos" in name:
            return jnp.full(s.shape, -1, s.dtype)
        return jnp.zeros(s.shape, s.dtype)

    return jax.tree_util.tree_map_with_path(init, shapes)


def decode_step(cfg: ModelConfig, params, state, batch):
    return module_for(cfg).decode_step(cfg, params, state, batch)


def prefill(cfg: ModelConfig, params, batch):
    """Full-sequence forward returning logits (+ cache where the family
    supports prefill-into-cache)."""
    return forward(cfg, params, batch, return_cache=True)


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    shapes = param_shapes(cfg, jnp.float32)
    total = 0
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        n = int(np.prod(leaf.shape))
        if active_only and "experts/" in name:
            n = n * cfg.moe_top_k // cfg.moe_experts
        total += n
    return total


# ---------------------------------------------------------------------------
# Batch construction (ShapeDtypeStructs for the dry-run; concrete for tests)
# ---------------------------------------------------------------------------


def train_batch_shapes(cfg: ModelConfig, batch: int, seq: int) -> dict:
    b = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    if cfg.family == "encdec":
        b["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.enc_frames, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        b["patch_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.num_patches, cfg.d_model), jnp.bfloat16)
        b["pos_ids"] = jax.ShapeDtypeStruct((3, batch, seq), jnp.int32)
    return b


def decode_batch_shapes(cfg: ModelConfig, batch: int) -> dict:
    b = {
        "tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if cfg.family == "vlm":
        b["pos_ids"] = jax.ShapeDtypeStruct((3, batch, 1), jnp.int32)
    return b


def make_train_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    b = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (batch, seq)), jnp.int32)}
    if cfg.family == "encdec":
        b["frames"] = jnp.asarray(
            rng.normal(0, 1, (batch, cfg.enc_frames, cfg.d_model)),
            jnp.bfloat16)
    if cfg.family == "vlm":
        b["patch_embeds"] = jnp.asarray(
            rng.normal(0, 1, (batch, cfg.num_patches, cfg.d_model)),
            jnp.bfloat16)
        pos = np.broadcast_to(np.arange(seq)[None, None], (3, batch, seq))
        b["pos_ids"] = jnp.asarray(pos.copy(), jnp.int32)
    return b


def make_decode_batch(cfg: ModelConfig, batch: int, pos: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    b = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (batch, 1)),
                              jnp.int32),
        "pos": jnp.asarray(pos, jnp.int32),
    }
    if cfg.family == "vlm":
        b["pos_ids"] = jnp.full((3, batch, 1), pos, jnp.int32)
    return b
