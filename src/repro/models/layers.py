"""Shared neural building blocks: norms, RoPE/M-RoPE, attention (full, local,
cross, flash-chunked), gated MLPs.

All computation follows mixed precision: parameters may be fp32 (training
master) or bf16 (serving); matmuls run in bf16 with fp32 softmax/norm
accumulation.  Activation sharding uses the logical axes of
:mod:`repro.parallel.sharding` and degrades to no-ops without a mesh.
"""
from __future__ import annotations

import functools
import math
from typing import Callable

import jax
import jax.numpy as jnp

from repro import compat
from repro.parallel.sharding import gather_safe_mode, shard

COMPUTE_DTYPE = jnp.bfloat16
# Above this many score elements per (batch, head) the attention switches to
# the chunked flash path to bound activation memory: a 2048×2048 fp32 score
# chunk is 16 MB per (batch, head) — the plain path at 4k×4k would cost 64 MB
# per (batch, head) and blow the per-device HBM at train_4k scale.
FLASH_THRESHOLD = 2048 * 2048
FLASH_CHUNK_Q = 1024
FLASH_CHUNK_K = 1024
NEG_INF = -1e30


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * (1.0 + 0.0 + w.astype(jnp.float32))).astype(dt)


def layer_norm(x, w, b, eps):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def remat_segments(n_layers: int) -> int:
    """√L-nested-remat segment count: the largest divisor of n_layers that
    is ≤ √n_layers (1 → plain scan).  Outer scan saves only segment-boundary
    carries; each segment recomputes its inner carries during backward —
    peak saved-activation memory drops from L·act to (L/segs + segs)·act at
    the cost of one extra forward recompute (§Perf iteration D3)."""
    import math
    best = 1
    for d in range(2, int(math.isqrt(n_layers)) + 1):
        if n_layers % d == 0:
            best = d
    return best


def segmented_scan(body, x, stacked_params, n_layers: int):
    """lax.scan over layers with √L nested remat (see remat_segments)."""
    segs = remat_segments(n_layers)
    if segs <= 1:
        return jax.lax.scan(body, x, stacked_params)
    per = n_layers // segs
    seg_params = jax.tree_util.tree_map(
        lambda a: a.reshape(segs, per, *a.shape[1:]), stacked_params)

    def seg_body(x, sp):
        return jax.lax.scan(body, x, sp)

    seg_body = jax.checkpoint(
        seg_body, policy=jax.checkpoint_policies.nothing_saveable)
    x, ys = jax.lax.scan(seg_body, x, seg_params)
    ys = jax.tree_util.tree_map(
        lambda a: a.reshape(n_layers, *a.shape[2:]), ys)
    return x, ys


def embed_lookup(embed: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    """Embedding lookup.  Inside partial-manual shard_map regions the gather
    is replaced by a one-hot contraction (see sharding.gather_safe_mode).

    The optimization barrier pins the fp32→bf16 convert BEFORE the gather:
    with a vocab-sharded table the partitioned gather ends in an all-reduce,
    and without the barrier XLA reorders the convert after it, all-reducing
    fp32 — measured 537 MB/step vs 268 MB on llama train_4k (§Perf D2)."""
    if gather_safe_mode():
        oh = jax.nn.one_hot(tokens, embed.shape[0], dtype=embed.dtype)
        return oh @ embed
    embed = compat.opt_barrier(embed)
    return embed[tokens]


def wcast(w: jnp.ndarray, dtype) -> jnp.ndarray:
    """Weight cast pinned BEFORE any FSDP gather: without the barrier XLA
    reorders the fp32→bf16 convert after the all-gather and moves fp32
    weight bytes over the fabric (measured 0.97 GB vs 0.48 GB per MLP matrix
    on qwen2-vl train_4k, §Perf D4)."""
    if w.dtype == dtype:
        return w
    return compat.opt_barrier(w.astype(dtype))


def act_fn(name: str) -> Callable:
    return {"silu": jax.nn.silu, "gelu": functools.partial(jax.nn.gelu, approximate=True),
            "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def rope_cos_sin(positions: jnp.ndarray, head_dim: int, theta: float):
    """positions (..., S) int32 → cos/sin (..., S, head_dim//2) fp32."""
    half = head_dim // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.cos(ang), jnp.sin(ang)


def mrope_cos_sin(positions: jnp.ndarray, head_dim: int, theta: float,
                  sections: tuple[int, ...]):
    """M-RoPE (Qwen2-VL): positions (3, B, S) for (t, h, w); each frequency
    band uses the positional stream of its section."""
    half = head_dim // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    # (3, B, S, half)
    ang = positions.astype(jnp.float32)[..., None] * inv_freq
    parts_c, parts_s = [], []
    off = 0
    for s_idx, width in enumerate(sections):
        parts_c.append(jnp.cos(ang[s_idx, ..., off: off + width]))
        parts_s.append(jnp.sin(ang[s_idx, ..., off: off + width]))
        off += width
    return jnp.concatenate(parts_c, -1), jnp.concatenate(parts_s, -1)


def apply_rotary(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x (B, S, H, hd); cos/sin (B, S, hd//2) — llama 'rotate-half' layout."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]  # (B, S, 1, half)
    s = sin[..., None, :]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * c - x2f * s, x2f * c + x1f * s], axis=-1
    ).astype(dt)


# ---------------------------------------------------------------------------
# Masks
# ---------------------------------------------------------------------------


def band_mask(q_len: int, k_len: int, q_offset, window: int = 0):
    """(q_len, k_len) bool: causal (+ optional local window) band.
    ``q_offset`` is the absolute position of query row 0 (static or traced)."""
    qi = jnp.arange(q_len)[:, None] + q_offset
    ki = jnp.arange(k_len)[None, :]
    m = ki <= qi
    if window:
        m &= ki > qi - window
    return m


# ---------------------------------------------------------------------------
# Attention (GQA, full / chunked-flash)
# ---------------------------------------------------------------------------


def _decode_attention(q, k, v, kv_valid, scale):
    """Grouped one-token path: q (B,1,Hq,hd), k/v (B,T,Hkv,hd).  Keeps KV in
    grouped layout — decode is cache-read-bound and must not amplify bytes.
    The cache's seq axis may be sharded ('seq'→model); the softmax reduction
    over T is then XLA's distributed flash-decode."""
    b, _, hq, hd = q.shape
    hk = k.shape[2]
    g = hq // hk
    qg = q.reshape(b, 1, hk, g, hd)
    s = jnp.einsum("bsigd,btid->bigst", qg, k,
                   preferred_element_type=jnp.float32) * scale
    if kv_valid is not None:
        s = jnp.where(kv_valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bigst,btid->bsigd", p.astype(v.dtype), v)
    return o.reshape(b, 1, hq, hd)


def _plain_attention(q, k, v, mask, scale):
    """Repeated-KV layout: q/k/v (B,*,H,hd); head axis shardable over 'tp'."""
    s = jnp.einsum("bshd,bthd->bhst", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhst,bthd->bshd", p.astype(v.dtype), v)
    return o


def _flash_attention(q, k, v, mask_fn, scale, chunk_q, chunk_k):
    """Double-chunked online-softmax attention on the repeated-KV layout:
    outer map over query blocks, inner scan over KV blocks.  Peak score
    memory O(chunk_q · chunk_k) per (batch, head)."""
    b, s_len, h, hd = q.shape
    t_len = k.shape[1]
    pad_q = (-s_len) % chunk_q
    pad_k = (-t_len) % chunk_k
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq, nk = qp.shape[1] // chunk_q, kp.shape[1] // chunk_k

    kb = jnp.moveaxis(kp.reshape(b, nk, chunk_k, h, hd), 1, 0)
    vb = jnp.moveaxis(vp.reshape(b, nk, chunk_k, h, hd), 1, 0)
    qb = jnp.moveaxis(qp.reshape(b, nq, chunk_q, h, hd), 1, 0)

    def q_block(qi, qc):
        def kv_block(carry, inputs):
            m_run, l_run, acc = carry
            ki, kc, vc = inputs
            sc = jnp.einsum("bshd,bthd->bhst", qc, kc,
                            preferred_element_type=jnp.float32) * scale
            msk = mask_fn(qi * chunk_q, chunk_q, ki * chunk_k, chunk_k)
            sc = jnp.where(msk, sc, NEG_INF)
            m_new = jnp.maximum(m_run, sc.max(axis=-1))
            corr = jnp.exp(m_run - m_new)
            p = jnp.exp(sc - m_new[..., None])
            l_new = l_run * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhst,bthd->bhsd", p.astype(vc.dtype), vc)
            acc_new = acc * corr[..., None].astype(acc.dtype) + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, chunk_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, chunk_q), jnp.float32)
        a0 = jnp.zeros((b, h, chunk_q, hd), v.dtype)
        # flash-style backward: recompute the score chunk instead of saving
        # it — without this the scan stacks (nq·nk) fp32 score chunks, i.e.
        # the full S×T score matrix the flash path exists to avoid.
        kv_body = jax.checkpoint(
            kv_block, policy=jax.checkpoint_policies.nothing_saveable)
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_body, (m0, l0, a0), (jnp.arange(nk), kb, vb))
        o = acc / jnp.maximum(l_f, 1e-30)[..., None].astype(acc.dtype)
        return jnp.moveaxis(o, 2, 1)  # (B, chunk_q, H, hd)

    out = jax.lax.map(lambda args: q_block(*args), (jnp.arange(nq), qb))
    out = jnp.moveaxis(out, 0, 1).reshape(b, nq * chunk_q, h, hd)
    return out[:, :s_len]


def gqa_attention(
    q: jnp.ndarray,            # (B, S, Hq, hd)
    k: jnp.ndarray,            # (B, T, Hkv, hd)
    v: jnp.ndarray,            # (B, T, Hkv, hd)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset=0,
    kv_valid: jnp.ndarray | None = None,  # (B, T) bool — decode-cache mask
) -> jnp.ndarray:
    """Grouped-query attention: grouped one-token path for decode, repeated-KV
    (head-sharded) full/flash paths for train/prefill."""
    b, s_len, hq, hd = q.shape
    t_len = k.shape[1]
    hk = k.shape[2]
    g = hq // hk
    scale = 1.0 / math.sqrt(hd)

    if s_len == 1:
        return _decode_attention(q, k, v, kv_valid, scale)

    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    k = shard(k, "batch", None, "tp", None)
    v = shard(v, "batch", None, "tp", None)

    if s_len * t_len <= FLASH_THRESHOLD:
        if causal or window:
            m = band_mask(s_len, t_len, q_offset, window)[None, None]
        else:
            m = jnp.ones((s_len, t_len), bool)[None, None]
        if kv_valid is not None:
            m = m & kv_valid[:, None, None, :]
        o = _plain_attention(q, k, v, m, scale)
    else:
        def mask_fn(q0, ql, k0, kl):
            qi = jnp.arange(ql)[:, None] + q0 + q_offset
            ki = jnp.arange(kl)[None, :] + k0
            m_ = ki < t_len
            if causal:
                m_ = m_ & (ki <= qi)
            if window:
                m_ = m_ & (ki > qi - window)
            return m_[None, None]

        o = _flash_attention(q, k, v, mask_fn, scale,
                             FLASH_CHUNK_Q, FLASH_CHUNK_K)
    return o.reshape(b, s_len, hq, hd)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp(x, p, act: str, glu: bool):
    """Gated (SwiGLU/GeGLU) or plain 2-layer MLP.  x (..., d)."""
    f = act_fn(act)
    h_up = x @ wcast(p["w_up"], x.dtype)
    if glu:
        h = f(x @ wcast(p["w_gate"], x.dtype)) * h_up
    else:
        h = f(h_up)
    if h.ndim == 3:
        h = shard(h, "batch", "seq", None)
    else:  # (tokens, ff) — MoE shared-expert path
        h = shard(h, "tokens", None)
    return h @ wcast(p["w_down"], x.dtype)


# ---------------------------------------------------------------------------
# Parameter tree construction helpers
# ---------------------------------------------------------------------------


def dense(d_in: int, d_out: int, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((d_in, d_out), dtype)


def vec(n: int, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((n,), dtype)


def init_from_shapes(shapes, key, scale: float = 0.02):
    """Materialise a ShapeDtypeStruct pytree with N(0, scale²) weights (norm
    'scale'/'bias' leaves get zeros — note rms_norm uses (1 + w))."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)

    def init_leaf(path_leaf):
        path, sds = path_leaf
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        sub = jax.random.fold_in(key, hash(name) % (2**31))
        if sds.ndim <= 1 or "norm" in name or "scale" in name or name.endswith("_b"):
            return jnp.zeros(sds.shape, sds.dtype)
        return (jax.random.normal(sub, sds.shape, jnp.float32) * scale).astype(sds.dtype)

    leaves = [init_leaf(pl) for pl in flat]
    return jax.tree_util.tree_unflatten(treedef, leaves)
