"""Mixture-of-Experts transformer (granite-moe, qwen2-moe).

Routing: softmax router, top-k selection, renormalised gates, capacity-based
token dropping (capacity_factor, GShard-style).

Expert parallelism follows the paper's broadcast doctrine (DESIGN.md Sec 4)
as an explicit shard_map over the mesh: tokens are ALL-GATHERED within each
model group (the query broadcast), every model rank dispatches into its
OWNED expert chunk with purely local scatters/gathers (the local leaf scan —
letting GSPMD partition a global-capacity scatter replicates 45 GB index
buffers per device; measured in the §Perf log), and partial outputs are
REDUCE-SCATTERED back (the count psum).  Expert counts that do not divide
the model axis (60, 40 on a 16-way axis) are zero-padded; padding experts
receive no routes.

qwen2-moe additionally has 4 "shared experts" fused into one always-on MLP
(hidden 4·1408 = 5632) gated by a sigmoid projection, per the HF reference;
the shared path runs in plain GSPMD outside the shard_map.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.base import ModelConfig
from repro.parallel.sharding import shard


def layer_shapes(cfg: ModelConfig, dtype) -> dict:
    d, e, f = cfg.d_model, cfg.moe_experts, cfg.d_ff
    p = T.layer_shapes(cfg, dtype)
    # replace the dense FFN with router + experts (+ optional shared expert)
    for k_ in ("w_gate", "w_up", "w_down"):
        p.pop(k_, None)
    p["router"] = L.dense(d, e, dtype)
    p["experts"] = {
        "w_gate": jax.ShapeDtypeStruct((e, d, f), dtype),
        "w_up": jax.ShapeDtypeStruct((e, d, f), dtype),
        "w_down": jax.ShapeDtypeStruct((e, f, d), dtype),
    }
    if cfg.moe_shared_ff:
        p["shared"] = {
            "w_gate": L.dense(d, cfg.moe_shared_ff, dtype),
            "w_up": L.dense(d, cfg.moe_shared_ff, dtype),
            "w_down": L.dense(cfg.moe_shared_ff, d, dtype),
        }
        p["shared_gate"] = L.dense(d, 1, dtype)
    return p


def param_shapes(cfg: ModelConfig, dtype=jnp.float32) -> dict:
    stacked = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((cfg.n_layers,) + s.shape, s.dtype),
        layer_shapes(cfg, dtype),
    )
    p = {
        "embed": jax.ShapeDtypeStruct((cfg.vocab, cfg.d_model), dtype),
        "final_norm": L.vec(cfg.d_model, dtype),
        "layers": stacked,
        "lm_head": L.dense(cfg.d_model, cfg.vocab, dtype),
    }
    return p


def _expert_pad(cfg: ModelConfig, m_sz: int) -> int:
    """Pad the expert count to a multiple of the model-axis size so the
    expert dim shards cleanly (60→64, 40→48 on a 16-way axis); padding
    experts get zero weights and are never routed to (the router only has
    logits for real experts)."""
    return -(-cfg.moe_experts // m_sz) * m_sz


def _local_dispatch_ffn(cfg, xf, rw, wg, wu, wd, epm, owned_offset, cap):
    """Single-device capacity-based dispatch for an expert chunk.

    xf (T, d) tokens, rw (d, E) router, w* (epm, …) the owned expert chunk
    starting at expert id ``owned_offset``.  All scatters/gathers here are
    LOCAL (this runs inside shard_map or on one device) — GSPMD never has to
    partition them, which is the whole point: the global-capacity scatter
    does not partition (XLA replicates the full buffer).

    Buffer-side formulation keeps memory O(epm·cap·d): token *indices* are
    scattered into the buffer, token rows are gathered buffer-side, and the
    combine is a buffer-side scatter-add.  Returns y (T, d): the summed
    contribution of the owned experts only.
    """
    tg, d = xf.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    logits = (xf @ rw.astype(xf.dtype)).astype(jnp.float32)     # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, k)                        # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    rel = ids - owned_offset                                    # (T, k)
    own = (rel >= 0) & (rel < epm)
    flat_rel = jnp.where(own, rel, epm).reshape(-1)             # epm = drop
    flat_gate = jnp.where(own, gates, 0.0).reshape(-1)
    src_tok = jnp.arange(tg * k, dtype=jnp.int32) // k

    # position of each assignment within its expert queue
    oh = (flat_rel[:, None] == jnp.arange(epm)[None, :]).astype(jnp.int32)
    pos_all = jnp.cumsum(oh, axis=0) - oh                       # (T·k, epm)
    pos = jnp.take_along_axis(
        pos_all, jnp.minimum(flat_rel, epm - 1)[:, None], axis=1)[:, 0]
    keep = (flat_rel < epm) & (pos < cap)
    idx_e = jnp.where(keep, flat_rel, 0)
    idx_c = jnp.where(keep, pos, cap)

    # scatter token *ids* and gates into the buffer (drop row = cap)
    buf_src = jnp.full((epm, cap + 1), tg, jnp.int32)
    buf_src = buf_src.at[idx_e, idx_c].set(jnp.where(keep, src_tok, tg))
    buf_gate = jnp.zeros((epm, cap + 1), jnp.float32)
    buf_gate = buf_gate.at[idx_e, idx_c].set(jnp.where(keep, flat_gate, 0.0))

    xf_pad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    buf = xf_pad[buf_src]                                       # (epm, C+1, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg.astype(xf.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, wu.astype(xf.dtype))
    out_buf = jnp.einsum("ecf,efd->ecd", h, wd.astype(xf.dtype))

    contrib = out_buf * buf_gate[..., None].astype(out_buf.dtype)
    y = jnp.zeros((tg + 1, d), xf.dtype)
    y = y.at[buf_src.reshape(-1)].add(
        contrib.reshape(-1, d), mode="drop")
    return y[:tg]


def moe_ffn(cfg: ModelConfig, lp: dict, x: jnp.ndarray) -> jnp.ndarray:
    """x (B, S, d) → (B, S, d) via top-k routed experts.

    Distribution (DESIGN.md: the paper's broadcast doctrine applied to EP):
    tokens arrive sequence-sharded over \'model\'; each model rank ALL-GATHERS
    its group\'s tokens (the query broadcast), runs the local expert chunk\'s
    capacity-based dispatch entirely on-device (the local leaf scan), and the
    per-rank partial outputs are REDUCE-SCATTERED back (the count psum).
    Data-parallel rows replicate the expert weights; their gradients reduce
    over \'data\' automatically through the shard_map transpose."""
    from repro.parallel.sharding import current_mesh, excluded_axes
    mesh = current_mesh()
    b, s, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_top_k

    if mesh is None or "model" not in mesh.axis_names \
            or mesh.shape["model"] == 1 or excluded_axes():
        # single-device / no-EP path: one chunk holding every expert
        xf = x.reshape(b * s, d)
        cap = int(b * s * k * cfg.capacity_factor / e) + 1
        y = _local_dispatch_ffn(
            cfg, xf, lp["router"], lp["experts"]["w_gate"],
            lp["experts"]["w_up"], lp["experts"]["w_down"],
            epm=e, owned_offset=0, cap=cap)
    else:
        m_sz = mesh.shape["model"]
        ep = _expert_pad(cfg, m_sz)
        epm = ep // m_sz
        wg, wu, wd = (lp["experts"][n] for n in ("w_gate", "w_up", "w_down"))
        if ep != e:
            padw = ((0, ep - e), (0, 0), (0, 0))
            wg, wu, wd = (jnp.pad(w_, padw) for w_ in (wg, wu, wd))
        dp_axes = tuple(a for a in ("pod", "data")
                        if a in mesh.axis_names and a not in excluded_axes())
        seq_sharded = s % m_sz == 0 and s > 1
        x_spec = jax.sharding.PartitionSpec(
            dp_axes or None, "model" if seq_sharded else None, None)
        w_spec = jax.sharding.PartitionSpec("model", None, None)

        def body(xl, rw, wgl, wul, wdl):
            bl, sl, _ = xl.shape
            if seq_sharded:
                xg = jax.lax.all_gather(
                    xl, "model", axis=1, tiled=True)        # (bl, S, d)
            else:
                xg = xl
            tg = bl * xg.shape[1]
            j = jax.lax.axis_index("model")
            cap = int(tg * k * cfg.capacity_factor / e) + 1
            y = _local_dispatch_ffn(
                cfg, xg.reshape(tg, d), rw, wgl, wul, wdl,
                epm=epm, owned_offset=j * epm, cap=cap)
            y = y.reshape(bl, xg.shape[1], d)
            if seq_sharded:
                return jax.lax.psum_scatter(
                    y, "model", scatter_dimension=1, tiled=True)
            return jax.lax.psum(y, "model")

        y = compat.shard_map(
            body, mesh=mesh,
            in_specs=(x_spec, jax.sharding.PartitionSpec(),
                      w_spec, w_spec, w_spec),
            out_specs=x_spec,
            axis_names={"model"} | set(dp_axes), check_vma=False,
        )(x, lp["router"], wg, wu, wd).reshape(b * s, d)
        xf = x.reshape(b * s, d)

    if cfg.moe_shared_ff:
        sh = lp["shared"]
        xf = shard(x.reshape(b * s, d), "tokens", None)
        shared = L.mlp(xf, sh, cfg.act, True)
        sg = jax.nn.sigmoid(
            (xf @ lp["shared_gate"].astype(xf.dtype)).astype(jnp.float32))
        y = y + shared * sg.astype(shared.dtype)
    return y.reshape(b, s, d)


def _block(cfg: ModelConfig, lp, x, cos, sin):
    # see transformer.forward: pin the scan carry against convert hoisting
    x = compat.opt_barrier(x)
    x, kv = T.attn_block(cfg, lp, x, cos, sin, window=cfg.window)
    h = L.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    x = x + moe_ffn(cfg, lp, h)
    return shard(x, "batch", "seq", None), kv


def forward(cfg: ModelConfig, params, batch, *, return_cache: bool = False,
            return_hidden: bool = False):
    x = T.embed_tokens(cfg, params, batch)
    cos, sin = T.rope_for(cfg, batch, x.shape[1])

    body = lambda c, lp: _block(cfg, lp, c, cos, sin)
    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        x, caches = L.segmented_scan(body, x, params["layers"], cfg.n_layers)
    else:
        ks, vs = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            x, (k_, v_) = body(x, lp)
            ks.append(k_)
            vs.append(v_)
        caches = (jnp.stack(ks), jnp.stack(vs))

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x
    logits = x @ params["lm_head"].astype(x.dtype)
    logits = shard(logits, "batch", None, "tp")
    if return_cache:
        return logits, caches
    return logits


decode_state_shapes = T.decode_state_shapes


def decode_step(cfg: ModelConfig, params, state, batch):
    pos = batch["pos"]
    x = T.embed_tokens(cfg, params, batch)
    bsz = batch["tokens"].shape[0]
    p = jnp.broadcast_to(pos[None, None], (bsz, 1)).astype(jnp.int32)
    cos, sin = L.rope_cos_sin(p, cfg.head_dim, cfg.rope_theta)

    def block(x, per_layer):
        lp, kc, vc = per_layer
        x, kc, vc = T.attn_block_decode(cfg, lp, x, cos, sin, kc, vc, pos,
                                        window=cfg.window)
        h = L.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
        x = x + moe_ffn(cfg, lp, h)
        return x, (kc, vc)

    if cfg.scan_layers:
        x, (k_new, v_new) = jax.lax.scan(
            block, x, (params["layers"], state["k"], state["v"]))
    else:
        ks, vs = [], []
        for i in range(cfg.n_layers):
            per = jax.tree_util.tree_map(
                lambda a: a[i], (params["layers"], state["k"], state["v"]))
            x, (kc, vc) = block(x, per)
            ks.append(kc)
            vs.append(vc)
        k_new, v_new = jnp.stack(ks), jnp.stack(vs)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"].astype(x.dtype)
    return logits, {"k": k_new, "v": v_new}
