"""Unified model configuration for the assigned architecture pool.

One :class:`ModelConfig` covers every family (dense GQA transformers, MoE,
mamba-1 SSM, RG-LRU hybrid, encoder-decoder audio, VLM backbone).  Family
modules consume the fields relevant to them; `family` selects the module in
:mod:`repro.models.api`.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 → d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    act: str = "silu"           # mlp activation: silu (SwiGLU) | gelu (GeGLU/plain)
    glu: bool = True            # gated MLP (SwiGLU/GeGLU) vs plain 2-layer

    # --- MoE -------------------------------------------------------------
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_shared_ff: int = 0      # shared-expert hidden size (qwen2-moe: 5632)
    capacity_factor: float = 1.25

    # --- SSM (mamba-1) -----------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0            # 0 → ceil(d_model / 16)

    # --- hybrid (griffin / RG-LRU) ----------------------------------------
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    window: int = 0             # local-attention window (0 = full/causal)
    lru_width: int = 0          # 0 → d_model
    conv_width: int = 4

    # --- encoder-decoder (whisper) ----------------------------------------
    enc_layers: int = 0
    enc_frames: int = 1500      # stub frontend output length
    learned_pos: bool = False

    # --- VLM (qwen2-vl) ----------------------------------------------------
    mrope_sections: Tuple[int, ...] = ()  # (t, h, w) half-dim splits
    num_patches: int = 256      # stub patch-embedding count per sample

    # --- scan/remat structure ----------------------------------------------
    scan_layers: bool = True    # lax.scan over stacked layers (small HLO)
    remat: bool = True

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        if self.family == "ssm" and self.dt_rank == 0:
            object.__setattr__(self, "dt_rank", -(-self.d_model // 16))
        if self.family == "hybrid" and self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def param_count(self) -> int:
        """Total parameters (for 6·N·D roofline arithmetic)."""
        from repro.models import api
        return api.count_params(self)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: routed top-k + shared only)."""
        from repro.models import api
        return api.count_params(self, active_only=True)


def validate(cfg: ModelConfig) -> None:
    assert cfg.family in ("dense", "moe", "ssm", "hybrid", "encdec", "vlm"), cfg.family
    if cfg.family not in ("ssm",):
        assert cfg.n_heads >= 1 and cfg.d_model % 1 == 0
        if cfg.n_kv_heads:
            assert cfg.n_heads % cfg.n_kv_heads == 0
    if cfg.family == "moe":
        assert cfg.moe_experts > 0 and cfg.moe_top_k > 0
    if cfg.family == "hybrid":
        assert cfg.block_pattern and cfg.window > 0
    if cfg.family == "vlm":
        assert sum(cfg.mrope_sections) == cfg.head_dim // 2
