"""Dense decoder-only transformer (llama/qwen family) + VLM backbone variant.

Layers are stacked along a leading axis and iterated with ``lax.scan`` so the
compiled HLO contains one layer body regardless of depth (essential for the
512-device dry-run compile times); ``jax.checkpoint`` remats the block in
training.  The VLM family (qwen2-vl) shares this module: M-RoPE position
streams and stubbed patch embeddings are injected through the batch dict.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro import compat

from repro.models import layers as L
from repro.models.base import ModelConfig
from repro.parallel.sharding import shard


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def layer_shapes(cfg: ModelConfig, dtype) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.n_heads * hd, cfg.n_kv_heads * hd
    p = {
        "attn_norm": L.vec(d, dtype),
        "wq": L.dense(d, hq, dtype),
        "wk": L.dense(d, hkv, dtype),
        "wv": L.dense(d, hkv, dtype),
        "wo": L.dense(hq, d, dtype),
        "mlp_norm": L.vec(d, dtype),
        "w_up": L.dense(d, cfg.d_ff, dtype),
        "w_down": L.dense(cfg.d_ff, d, dtype),
    }
    if cfg.glu:
        p["w_gate"] = L.dense(d, cfg.d_ff, dtype)
    if cfg.qkv_bias:
        p.update(wq_b=L.vec(hq, dtype), wk_b=L.vec(hkv, dtype),
                 wv_b=L.vec(hkv, dtype))
    return p


def param_shapes(cfg: ModelConfig, dtype=jnp.float32) -> dict:
    stacked = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((cfg.n_layers,) + s.shape, s.dtype),
        layer_shapes(cfg, dtype),
    )
    p = {
        "embed": jax.ShapeDtypeStruct((cfg.vocab, cfg.d_model), dtype),
        "final_norm": L.vec(cfg.d_model, dtype),
        "layers": stacked,
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense(cfg.d_model, cfg.vocab, dtype)
    return p


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _qkv(cfg: ModelConfig, lp: dict, h: jnp.ndarray):
    b, s, _ = h.shape
    hd = cfg.head_dim
    q = h @ L.wcast(lp["wq"], h.dtype)
    k = h @ L.wcast(lp["wk"], h.dtype)
    v = h @ L.wcast(lp["wv"], h.dtype)
    if cfg.qkv_bias:
        q = q + lp["wq_b"].astype(h.dtype)
        k = k + lp["wk_b"].astype(h.dtype)
        v = v + lp["wv_b"].astype(h.dtype)
    q = shard(q.reshape(b, s, cfg.n_heads, hd), "batch", None, "tp", None)
    k = shard(k.reshape(b, s, cfg.n_kv_heads, hd), "batch", None, "tp", None)
    v = shard(v.reshape(b, s, cfg.n_kv_heads, hd), "batch", None, "tp", None)
    return q, k, v


def attn_block(cfg: ModelConfig, lp: dict, x: jnp.ndarray, cos, sin,
               *, window: int = 0):
    """Full-sequence (train/prefill) attention sub-block.  Returns the
    residual-updated activations and this layer's (k, v) for cache capture."""
    h = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q, k, v = _qkv(cfg, lp, h)
    q = L.apply_rotary(q, cos, sin)
    k = L.apply_rotary(k, cos, sin)
    o = L.gqa_attention(q, k, v, causal=True, window=window)
    b, s, _, _ = o.shape
    o = o.reshape(b, s, cfg.n_heads * cfg.head_dim)
    x = x + o @ L.wcast(lp["wo"], x.dtype)
    return x, (k, v)


def attn_block_decode(cfg: ModelConfig, lp: dict, x, cos, sin,
                      k_cache, v_cache, pos, *, window: int = 0):
    """One-token decode attention against a (B, Smax, Hkv, hd) cache slice."""
    h = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q, k, v = _qkv(cfg, lp, h)            # S == 1
    q = L.apply_rotary(q, cos, sin)
    k = L.apply_rotary(k, cos, sin)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype),
                                           (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype),
                                           (0, pos, 0, 0))
    t = k_cache.shape[1]
    ki = jnp.arange(t)
    valid = ki <= pos
    if window:
        valid &= ki > pos - window
    o = L.gqa_attention(q, k_cache, v_cache, causal=False,
                        kv_valid=jnp.broadcast_to(valid, (x.shape[0], t)))
    o = o.reshape(x.shape[0], 1, cfg.n_heads * cfg.head_dim).astype(x.dtype)
    x = x + o @ lp["wo"].astype(x.dtype)
    return x, k_cache, v_cache


def mlp_block(cfg: ModelConfig, lp: dict, x: jnp.ndarray):
    h = L.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    return x + L.mlp(h, lp, cfg.act, cfg.glu)


def _block_train(cfg: ModelConfig, lp, x, cos, sin):
    x, _ = attn_block(cfg, lp, x, cos, sin, window=cfg.window)
    x = mlp_block(cfg, lp, x)
    return shard(x, "batch", "seq", None)


# ---------------------------------------------------------------------------
# Embedding / positions
# ---------------------------------------------------------------------------


def embed_tokens(cfg: ModelConfig, params, batch) -> jnp.ndarray:
    tokens = batch["tokens"]
    x = L.embed_lookup(params["embed"].astype(L.COMPUTE_DTYPE), tokens)
    if cfg.family == "vlm" and "patch_embeds" in batch:
        # Stubbed modality frontend: precomputed patch embeddings occupy the
        # first `num_patches` positions of the sequence.
        pe = batch["patch_embeds"].astype(x.dtype)
        x = jnp.concatenate([pe, x[:, pe.shape[1]:]], axis=1)
    return shard(x, "batch", "seq", None)


def rope_for(cfg: ModelConfig, batch, seq_len: int, offset=0):
    if cfg.family == "vlm" and "pos_ids" in batch:
        return L.mrope_cos_sin(batch["pos_ids"], cfg.head_dim, cfg.rope_theta,
                               cfg.mrope_sections)
    b = batch["tokens"].shape[0]
    pos = jnp.arange(seq_len, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (b, seq_len))
    return rope_cos_sin_cached(pos, cfg.head_dim, cfg.rope_theta)


def rope_cos_sin_cached(pos, head_dim, theta):
    return L.rope_cos_sin(pos, head_dim, theta)


# ---------------------------------------------------------------------------
# Forward / loss / decode
# ---------------------------------------------------------------------------


def forward(cfg: ModelConfig, params, batch, *, return_cache: bool = False,
            return_hidden: bool = False):
    """Logits over the full sequence; optionally the per-layer (k, v) cache
    stack (prefill), or the final-norm hidden states (chunked loss)."""
    x = embed_tokens(cfg, params, batch)
    cos, sin = rope_for(cfg, batch, x.shape[1])

    def block(x, lp):
        # pin the carry inside the loop: without this XLA hoists the
        # bf16->f32 convert of the whole (L, B, S, d) saved-carry stack out
        # of the backward while-loop (measured 10.7 GB extra on qwen2-vl-72b)
        x = compat.opt_barrier(x)
        x, kv = attn_block(cfg, lp, x, cos, sin, window=cfg.window)
        x = mlp_block(cfg, lp, x)
        return shard(x, "batch", "seq", None), kv

    body = block
    if cfg.remat:
        body = jax.checkpoint(
            block, policy=jax.checkpoint_policies.nothing_saveable)

    if cfg.scan_layers:
        x, caches = L.segmented_scan(
            lambda c, lp: body(c, lp), x, params["layers"], cfg.n_layers)
    else:
        ks, vs = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            x, (k, v) = body(x, lp)
            ks.append(k)
            vs.append(v)
        caches = (jnp.stack(ks), jnp.stack(vs))

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x
    head = params.get("lm_head")
    if head is None:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    else:
        logits = x @ head.astype(x.dtype)
    logits = shard(logits, "batch", None, "tp")
    if return_cache:
        return logits, caches
    return logits


def decode_state_shapes(cfg: ModelConfig, batch_size: int, seq_len: int,
                        dtype=jnp.bfloat16) -> dict:
    kv = jax.ShapeDtypeStruct(
        (cfg.n_layers, batch_size, seq_len, cfg.n_kv_heads, cfg.head_dim),
        dtype)
    return {"k": kv, "v": kv}


def decode_step(cfg: ModelConfig, params, state: dict, batch: dict):
    """One-token decode: batch = {tokens (B,1), pos scalar int32, [pos_ids]}.
    Returns (logits (B,1,V), new_state)."""
    pos = batch["pos"]
    x = embed_tokens(cfg, params, batch)
    if cfg.family == "vlm" and "pos_ids" in batch:
        cos, sin = L.mrope_cos_sin(batch["pos_ids"], cfg.head_dim,
                                   cfg.rope_theta, cfg.mrope_sections)
    else:
        b = batch["tokens"].shape[0]
        p = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)
        cos, sin = L.rope_cos_sin(p, cfg.head_dim, cfg.rope_theta)

    def block(x, per_layer):
        lp, kc, vc = per_layer
        x, kc, vc = attn_block_decode(cfg, lp, x, cos, sin, kc, vc, pos,
                                      window=cfg.window)
        x = mlp_block(cfg, lp, x)
        return x, (kc, vc)

    if cfg.scan_layers:
        x, (k_new, v_new) = jax.lax.scan(
            block, x, (params["layers"], state["k"], state["v"]))
    else:
        ks, vs = [], []
        for i in range(cfg.n_layers):
            per = jax.tree_util.tree_map(
                lambda a: a[i], (params["layers"], state["k"], state["v"]))
            x, (kc, vc) = block(x, per)
            ks.append(kc)
            vs.append(vc)
        k_new, v_new = jnp.stack(ks), jnp.stack(vs)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    else:
        logits = x @ head.astype(x.dtype)
    return logits, {"k": k_new, "v": v_new}
