"""RG-LRU + local-attention hybrid (recurrentgemma-2b, Griffin architecture).

Block pattern (rec, rec, attn) repeats over the depth; 26 layers → 8 full
pattern units + 2 trailing recurrent blocks.  The pattern units are stacked
and scanned (one compiled unit body), the tail is scanned separately — the
HLO contains exactly two block bodies.

Recurrent block: x → [linear → GeLU] gate branch ⊗ [linear → causal conv →
RG-LRU] → linear out.  RG-LRU (Griffin eq. 3-4):

    r_t = σ(W_a x_t + b_a)          recurrence gate
    i_t = σ(W_x x_t + b_x)          input gate
    log a_t = -c · softplus(Λ) · r_t          (c = 8)
    h_t = a_t ⊙ h_{t-1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

The recurrence reuses the chunked scan machinery of :mod:`repro.models.ssm`
(lax.scan over chunks, associative scan within).  Local attention uses MQA
(kv = 1) with a 2048 window; its decode cache is a ring buffer of `window`
slots — combined with the O(1) recurrent state this bounds decode memory and
is why recurrentgemma runs the long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat

from repro.models import layers as L
from repro.models import transformer as T
from repro.models.base import ModelConfig
from repro.parallel.sharding import shard

LRU_C = 8.0
SCAN_CHUNK = 256


def rec_layer_shapes(cfg: ModelConfig, dtype) -> dict:
    d, w = cfg.d_model, cfg.lru_width
    return {
        "norm": L.vec(d, dtype),
        "rg_x": L.dense(d, w, dtype),
        "rg_gate": L.dense(d, w, dtype),
        "conv_w": jax.ShapeDtypeStruct((w, cfg.conv_width), dtype),
        "conv_b": L.vec(w, dtype),
        "rg_a_w": L.dense(w, w, dtype),
        "rg_a_b": L.vec(w, dtype),
        "rg_i_w": L.dense(w, w, dtype),
        "rg_i_b": L.vec(w, dtype),
        "lambda_p": L.vec(w, dtype),
        "rg_out": L.dense(w, d, dtype),
        "mlp_norm": L.vec(d, dtype),
        "w_gate": L.dense(d, cfg.d_ff, dtype),
        "w_up": L.dense(d, cfg.d_ff, dtype),
        "w_down": L.dense(cfg.d_ff, d, dtype),
    }


def _stack(shapes, n):
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), shapes)


def param_shapes(cfg: ModelConfig, dtype=jnp.float32) -> dict:
    pat = cfg.block_pattern
    n_units, tail = divmod(cfg.n_layers, len(pat))
    unit = {}
    for idx, kind in enumerate(pat):
        shapes = (rec_layer_shapes(cfg, dtype) if kind == "rec"
                  else T.layer_shapes(cfg, dtype))
        unit[f"b{idx}_{kind}"] = _stack(shapes, n_units)
    p = {
        "embed": jax.ShapeDtypeStruct((cfg.vocab, cfg.d_model), dtype),
        "final_norm": L.vec(cfg.d_model, dtype),
        "units": unit,
    }
    if tail:
        tail_shapes = {}
        for idx in range(tail):
            kind = pat[idx]
            shapes = (rec_layer_shapes(cfg, dtype) if kind == "rec"
                      else T.layer_shapes(cfg, dtype))
            tail_shapes[f"t{idx}_{kind}"] = shapes
        p["tail"] = tail_shapes
    p["lm_head"] = L.dense(cfg.d_model, cfg.vocab, dtype)
    return p


# ---------------------------------------------------------------------------
# RG-LRU recurrence
# ---------------------------------------------------------------------------


def _rglru_gates(lp, x1):
    """a_t (B,S,w) fp32 decay, beta·i·x (B,S,w) fp32 input contribution."""
    r = jax.nn.sigmoid(
        (x1 @ lp["rg_a_w"].astype(x1.dtype)).astype(jnp.float32)
        + lp["rg_a_b"].astype(jnp.float32))
    i = jax.nn.sigmoid(
        (x1 @ lp["rg_i_w"].astype(x1.dtype)).astype(jnp.float32)
        + lp["rg_i_b"].astype(jnp.float32))
    log_a = -LRU_C * jax.nn.softplus(lp["lambda_p"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9))
    bx = beta * i * x1.astype(jnp.float32)
    return a, bx


def _chunked_lru_scan(a, bx, h0):
    """h_t = a_t ⊙ h_{t-1} + bx_t over (B, S, w) with chunked assoc. scan."""
    bsz, s, w = a.shape
    chunk = min(SCAN_CHUNK, s)
    pad = (-s) % chunk
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        bx = jnp.pad(bx, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // chunk
    ac = jnp.moveaxis(a.reshape(bsz, nc, chunk, w), 1, 0)
    bc = jnp.moveaxis(bx.reshape(bsz, nc, chunk, w), 1, 0)

    def chunk_step(h, inputs):
        a_c, b_c = inputs

        def combine(l, r_):
            al, bl = l
            ar, br = r_
            return al * ar, br + ar * bl

        a_cum, b_cum = jax.lax.associative_scan(combine, (a_c, b_c), axis=1)
        h_t = a_cum * h[:, None] + b_cum
        return h_t[:, -1], h_t

    h_f, hs = jax.lax.scan(chunk_step, h0, (ac, bc))
    h_seq = jnp.moveaxis(hs, 0, 1).reshape(bsz, s + pad, w)[:, :s]
    return h_seq, h_f


def rec_block(cfg: ModelConfig, lp, x, h0=None, conv_state=None, decode=False):
    """Recurrent residual block.  Full-sequence when decode=False."""
    bsz, s, _ = x.shape
    w = cfg.lru_width
    h = L.rms_norm(x, lp["norm"], cfg.norm_eps)
    gate = jax.nn.gelu(h @ lp["rg_gate"].astype(h.dtype))
    x1 = h @ lp["rg_x"].astype(h.dtype)
    x1 = shard(x1, "batch", None, "tp")
    if decode:
        conv_state = jnp.concatenate(
            [conv_state[:, 1:], x1.astype(conv_state.dtype)], axis=1)
        cw = lp["conv_w"].astype(jnp.float32)
        x1 = jnp.einsum("bkw,wk->bw",
                        conv_state.astype(jnp.float32), cw)
        x1 = (x1 + lp["conv_b"].astype(jnp.float32))[:, None].astype(x.dtype)
    else:
        k = cfg.conv_width
        xp = jnp.pad(x1, ((0, 0), (k - 1, 0), (0, 0)))
        x1 = sum(xp[:, j: j + s] * lp["conv_w"][:, j].astype(x1.dtype)
                 for j in range(k)) + lp["conv_b"].astype(x1.dtype)
    a, bx = _rglru_gates(lp, x1)
    if h0 is None:
        h0 = jnp.zeros((bsz, w), jnp.float32)
    if decode:
        h_new = a[:, 0] * h0 + bx[:, 0]
        y = h_new[:, None]
    else:
        y, h_new = _chunked_lru_scan(a, bx, h0)
    y = y.astype(x.dtype) * gate
    x = x + y @ lp["rg_out"].astype(x.dtype)
    # MLP sub-block (GeGLU)
    hm = L.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    x = x + L.mlp(hm, lp, cfg.act, cfg.glu)
    out_state = (conv_state, h_new) if decode else h_new
    return x, out_state


def _attn_block_train(cfg, lp, x, cos, sin):
    x, kv = T.attn_block(cfg, lp, x, cos, sin, window=cfg.window)
    x = T.mlp_block(cfg, lp, x)
    return x, kv


def forward(cfg: ModelConfig, params, batch, *, return_cache: bool = False,
            return_hidden: bool = False):
    tokens = batch["tokens"]
    x = L.embed_lookup(params["embed"].astype(L.COMPUTE_DTYPE), tokens)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)  # gemma-style scaling
    x = shard(x, "batch", "seq", None)
    cos, sin = T.rope_for(cfg, batch, x.shape[1])
    pat = cfg.block_pattern

    def unit_body(x, unit_params):
        # pin the scan carry against convert hoisting (see transformer)
        x = compat.opt_barrier(x)
        for idx, kind in enumerate(pat):
            lp = unit_params[f"b{idx}_{kind}"]
            if kind == "rec":
                x, _ = rec_block(cfg, lp, x)
            else:
                x, _ = _attn_block_train(cfg, lp, x, cos, sin)
        return shard(x, "batch", "seq", None), None

    body = unit_body
    if cfg.remat:
        body = jax.checkpoint(
            unit_body, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["units"])
    else:
        n_units = cfg.n_layers // len(pat)
        for u in range(n_units):
            up = jax.tree_util.tree_map(lambda a: a[u], params["units"])
            x, _ = body(x, up)
    for name, lp in params.get("tail", {}).items():
        kind = name.split("_")[1]
        if kind == "rec":
            x, _ = rec_block(cfg, lp, x)
        else:
            x, _ = _attn_block_train(cfg, lp, x, cos, sin)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x
    logits = x @ params["lm_head"].astype(x.dtype)
    logits = shard(logits, "batch", None, "tp")
    if return_cache:
        return logits, None
    return logits


# ---------------------------------------------------------------------------
# Decode: ring-buffer window cache for attention, O(1) recurrent states.
# ---------------------------------------------------------------------------


def _layer_kinds(cfg: ModelConfig) -> list[str]:
    pat = cfg.block_pattern
    return [pat[i % len(pat)] for i in range(cfg.n_layers)]


def decode_state_shapes(cfg: ModelConfig, batch_size: int, seq_len: int,
                        dtype=jnp.bfloat16) -> dict:
    del seq_len  # bounded by the attention window — sub-quadratic by design
    kinds = _layer_kinds(cfg)
    n_rec = sum(k == "rec" for k in kinds)
    n_att = sum(k == "attn" for k in kinds)
    w = cfg.window
    return {
        "conv": jax.ShapeDtypeStruct(
            (n_rec, batch_size, cfg.conv_width, cfg.lru_width), dtype),
        "h": jax.ShapeDtypeStruct(
            (n_rec, batch_size, cfg.lru_width), jnp.float32),
        "k": jax.ShapeDtypeStruct(
            (n_att, batch_size, w, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jax.ShapeDtypeStruct(
            (n_att, batch_size, w, cfg.n_kv_heads, cfg.head_dim), dtype),
        "slot_pos": jax.ShapeDtypeStruct((w,), jnp.int32),
    }


def decode_step(cfg: ModelConfig, params, state, batch):
    pos = batch["pos"]
    bsz = batch["tokens"].shape[0]
    x = params["embed"].astype(L.COMPUTE_DTYPE)[batch["tokens"]]
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    p = jnp.broadcast_to(pos[None, None], (bsz, 1)).astype(jnp.int32)
    cos, sin = L.rope_cos_sin(p, cfg.head_dim, cfg.rope_theta)

    slot = jnp.mod(pos, cfg.window)
    slot_pos = state["slot_pos"].at[slot].set(pos)
    valid = (slot_pos >= 0) & (slot_pos > pos - cfg.window)

    kinds = _layer_kinds(cfg)
    pat = cfg.block_pattern
    n_units = cfg.n_layers // len(pat)
    conv_new, h_new = list(state["conv"]), list(state["h"])
    k_new, v_new = list(state["k"]), list(state["v"])
    ri, ai = 0, 0
    for li, kind in enumerate(kinds):
        unit, off = divmod(li, len(pat))
        if unit < n_units:
            lp = jax.tree_util.tree_map(
                lambda a_: a_[unit], params["units"][f"b{off}_{kind}"])
        else:
            lp = params["tail"][f"t{off}_{kind}"]
        if kind == "rec":
            x, (cst, hst) = rec_block(cfg, lp, x, h0=state["h"][ri],
                                      conv_state=state["conv"][ri],
                                      decode=True)
            conv_new[ri], h_new[ri] = cst, hst
            ri += 1
        else:
            h_in = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
            q, k, v = T._qkv(cfg, lp, h_in)
            q = L.apply_rotary(q, cos, sin)
            k = L.apply_rotary(k, cos, sin)
            kc = jax.lax.dynamic_update_slice(
                state["k"][ai], k.astype(state["k"][ai].dtype),
                (0, slot, 0, 0))
            vc = jax.lax.dynamic_update_slice(
                state["v"][ai], v.astype(state["v"][ai].dtype),
                (0, slot, 0, 0))
            o = L.gqa_attention(q, kc, vc, causal=False,
                                kv_valid=jnp.broadcast_to(valid,
                                                          (bsz, cfg.window)))
            o = o.reshape(bsz, 1, cfg.n_heads * cfg.head_dim).astype(x.dtype)
            x = x + o @ lp["wo"].astype(x.dtype)
            x = T.mlp_block(cfg, lp, x)
            k_new[ai], v_new[ai] = kc, vc
            ai += 1

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"].astype(x.dtype)
    return logits, {
        "conv": jnp.stack(conv_new), "h": jnp.stack(h_new),
        "k": jnp.stack(k_new), "v": jnp.stack(v_new),
        "slot_pos": slot_pos,
    }
