"""minitron-8b — width-pruned nemotron dense transformer
[arXiv:2407.14679; hf].  32L, d_model 4096, 32H GQA kv=8, d_ff 16384,
vocab 256000."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=16384,
    vocab=256_000, head_dim=128,
)

SMOKE_CONFIG = ModelConfig(
    arch_id="minitron-8b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    head_dim=16,
)
