"""deepseek-coder-33b — llama-architecture dense code model
[arXiv:2401.14196; hf].  62L, d_model 7168, 56H GQA kv=8, d_ff 19200,
vocab 32256."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=19200,
    vocab=32_256, head_dim=128, rope_theta=100_000.0,
)

SMOKE_CONFIG = ModelConfig(
    arch_id="deepseek-coder-33b-smoke", family="dense",
    n_layers=2, d_model=56, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    head_dim=14,
)
