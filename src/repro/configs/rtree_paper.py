"""Spatial-engine configurations — the paper's own workloads (Table I).

These are registered alongside the LM architectures so the spatial engine is
a first-class citizen of the launcher/dry-run/roofline tooling.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SpatialConfig:
    name: str
    num_rects: int
    dataset: str             # repro.data.datasets key
    query_fractions: tuple = (0.01, 0.05, 0.10, 0.25)
    batch_size: int = 10_000  # paper: query batches of up to 10,000
    leaf_capacity: int = 0    # 0 → choose_parameters()
    fanout: int = 0
    kernel_tq: int = 512
    kernel_tr: int = 1024


SPORTS = SpatialConfig(name="rtree_sports", num_rects=999_000,
                       dataset="sports")
LAKES = SpatialConfig(name="rtree_lakes", num_rects=8_400_000,
                      dataset="lakes")
SYNTH16M = SpatialConfig(name="rtree_synth16m", num_rects=16_000_000,
                         dataset="synthetic")

SPATIAL_CONFIGS = {c.name: c for c in (SPORTS, LAKES, SYNTH16M)}


def get_spatial_config(name: str) -> SpatialConfig:
    return SPATIAL_CONFIGS[name]
