"""qwen2-moe-a2.7b — 60 routed experts top-4 + 4 shared experts
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf].  24L, d_model 2048, 16H (kv=16 → MHA),
per-expert d_ff 1408, shared hidden 5632, vocab 151936, QKV bias."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab=151_936, head_dim=128, qkv_bias=True,
    moe_experts=60, moe_top_k=4, moe_shared_ff=5632,
)

SMOKE_CONFIG = ModelConfig(
    arch_id="qwen2-moe-a2.7b-smoke", family="moe",
    n_layers=2, d_model=48, n_heads=4, n_kv_heads=4, d_ff=32, vocab=256,
    head_dim=12, qkv_bias=True, moe_experts=8, moe_top_k=2,
    moe_shared_ff=64,
)
