"""recurrentgemma-2b — RG-LRU + local attention hybrid, 1:2 attn:rec ratio
[arXiv:2402.19427; hf].  26L, d_model 2560, 10 heads (MQA kv=1, head_dim
256), GeGLU d_ff 7680, vocab 256000, window 2048."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
    vocab=256_000, head_dim=256, act="gelu", glu=True,
    block_pattern=("rec", "rec", "attn"), window=2048, lru_width=2560,
    conv_width=4, rope_theta=10_000.0,
)

SMOKE_CONFIG = ModelConfig(
    arch_id="recurrentgemma-2b-smoke", family="hybrid",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
    vocab=256, head_dim=16, act="gelu", glu=True,
    block_pattern=("rec", "rec", "attn"), window=16, lru_width=64,
    conv_width=4,
)
