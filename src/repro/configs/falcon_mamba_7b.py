"""falcon-mamba-7b — attention-free mamba-1 SSM [arXiv:2410.05355;
unverified].  64L, d_model 4096, d_inner 8192 (expand 2), ssm_state 16,
conv 4, dt_rank 256, vocab 65024."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab=65_024, ssm_state=16, ssm_conv=4, ssm_expand=2, dt_rank=256,
)

SMOKE_CONFIG = ModelConfig(
    arch_id="falcon-mamba-7b-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=1, n_kv_heads=1, d_ff=0, vocab=256,
    ssm_state=4, ssm_conv=4, ssm_expand=2, dt_rank=8,
)
