"""qwen2-vl-72b — VLM backbone with M-RoPE and stubbed patch embeddings
[arXiv:2409.12191; hf].  80L, d_model 8192, 64H GQA kv=8, d_ff 29568,
vocab 152064, QKV bias, mrope sections (16, 24, 24)."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
    vocab=152_064, head_dim=128, qkv_bias=True, rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24), num_patches=256,
)

SMOKE_CONFIG = ModelConfig(
    arch_id="qwen2-vl-72b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=256, head_dim=16, qkv_bias=True,
    mrope_sections=(2, 3, 3), num_patches=4,
)
