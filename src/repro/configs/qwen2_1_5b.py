"""qwen2-1.5b — dense GQA with QKV bias [arXiv:2407.10671; hf].
28L, d_model 1536, 12H GQA kv=2, d_ff 8960, vocab 151936."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-1.5b", family="dense",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_ff=8960,
    vocab=151_936, head_dim=128, qkv_bias=True, rope_theta=1_000_000.0,
)

SMOKE_CONFIG = ModelConfig(
    arch_id="qwen2-1.5b-smoke", family="dense",
    n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, d_ff=96, vocab=256,
    head_dim=12, qkv_bias=True,
)
