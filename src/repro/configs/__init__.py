"""Architecture registry: ``--arch <id>`` resolution for every launcher.

Each module defines ``CONFIG`` (the exact assigned configuration) and
``SMOKE_CONFIG`` (a reduced same-family configuration for CPU smoke tests).
The spatial-engine configs (the paper's own workloads) live in
:mod:`repro.configs.rtree_paper` and are registered under ``rtree_*`` ids.
"""
from __future__ import annotations

import importlib

from repro.models.base import ModelConfig

ARCH_IDS = [
    "recurrentgemma-2b",
    "qwen2-vl-72b",
    "minitron-8b",
    "deepseek-coder-33b",
    "llama3.2-1b",
    "qwen2-1.5b",
    "granite-moe-3b-a800m",
    "qwen2-moe-a2.7b",
    "whisper-medium",
    "falcon-mamba-7b",
]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}

# (seq_len, global_batch, step kind)
SHAPES = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG


def supports_long_context(cfg: ModelConfig) -> bool:
    """long_500k runs only for sub-quadratic archs (SSM state / bounded
    window); pure full-attention archs skip it (DESIGN.md Sec 4)."""
    return cfg.family == "ssm" or (cfg.family == "hybrid" and cfg.window > 0)


def cells(arch_id: str) -> list[str]:
    cfg = get_config(arch_id)
    out = []
    for name in SHAPES:
        if name == "long_500k" and not supports_long_context(cfg):
            continue
        out.append(name)
    return out


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in ARCH_IDS for s in cells(a)]
