"""whisper-medium — encoder-decoder audio backbone, conv frontend stubbed
[arXiv:2212.04356; unverified].  24+24L, d_model 1024, 16H MHA, d_ff 4096,
vocab 51865, 1500 encoder frames."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-medium", family="encdec",
    n_layers=24, enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51_865, head_dim=64, act="gelu", glu=False,
    enc_frames=1500, norm_eps=1e-5,
)

SMOKE_CONFIG = ModelConfig(
    arch_id="whisper-medium-smoke", family="encdec",
    n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, head_dim=16, act="gelu", glu=False,
    enc_frames=16, norm_eps=1e-5,
)
