"""granite-moe-3b-a800m — fine-grained MoE, 40 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf].  32L, d_model 1536,
24H GQA kv=8, per-expert d_ff 512, vocab 49155."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_ff=512,
    vocab=49_155, head_dim=64, moe_experts=40, moe_top_k=8,
)

SMOKE_CONFIG = ModelConfig(
    arch_id="granite-moe-3b-a800m-smoke", family="moe",
    n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, d_ff=32, vocab=256,
    head_dim=12, moe_experts=8, moe_top_k=2,
)
