"""Train-step factory and the fault-tolerant training driver.

``make_train_step`` builds the jitted SPMD step for a mesh:

* default (``cross_pod="auto"``): one GSPMD graph — FSDP parameter/optimizer
  sharding over 'data', TP over 'model', DP over ('pod','data'); XLA inserts
  and schedules all reductions (grad reduce-scatter/all-gather overlap with
  the backward pass).
* ``cross_pod="compressed"``: the step is wrapped in a partial-manual
  ``shard_map`` over the 'pod' axis only; each pod computes local grads via
  GSPMD (auto 'data'/'model'), then the cross-pod mean runs through the int8
  error-feedback reduction of :mod:`repro.parallel.compress` — modeling DCN
  bandwidth economy on real multi-pod systems.

``grad_accum`` > 1 splits the per-step batch into microbatches with a
``lax.scan`` (constant memory, XLA overlaps the microbatch reductions).

The driver (:func:`train`) adds the fault-tolerance substrate: step-indexed
deterministic data (restart-consistent), periodic checkpoints, auto-resume,
and a host-side straggler monitor (on multi-host deployments the monitor
feeds the coordination service; here it is unit-tested with synthetic
timings).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.data import tokens as tokmod
from repro.models import api
from repro.models.base import ModelConfig
from repro.parallel import compress
from repro.parallel.sharding import (
    logical_to_spec, param_shardings, param_specs, use_mesh)
from repro.train import checkpoint as ckpt
from repro.train.optimizer import AdamW


def _batch_shardings(cfg: ModelConfig, mesh: Mesh, batch_shapes: dict):
    out = {}
    for k, sds in batch_shapes.items():
        if k == "pos_ids":
            spec = logical_to_spec((None, "batch", None), mesh, sds.shape)
        elif sds.ndim >= 2:
            spec = logical_to_spec(
                ("batch",) + (None,) * (sds.ndim - 1), mesh, sds.shape)
        else:
            spec = P()
        out[k] = NamedSharding(mesh, spec)
    return out


def _microbatch(tree: dict, accum: int):
    """Split every batch-dim-leading leaf into (accum, b/accum, ...)."""
    def split(x):
        if x.ndim >= 2 and x.shape[0] % accum == 0 and x.shape[0] >= accum:
            return x.reshape(accum, x.shape[0] // accum, *x.shape[1:])
        return jnp.broadcast_to(x, (accum,) + x.shape)
    return jax.tree_util.tree_map(split, tree)


def make_train_step(
    cfg: ModelConfig,
    mesh: Mesh,
    opt: AdamW,
    *,
    grad_accum: int = 1,
    cross_pod: str = "auto",           # auto | compressed
    donate: bool = True,
):
    """Returns (step_fn, abstract_params, abstract_opt_state).

    step_fn(params, opt_state, batch) -> (params, opt_state, metrics)
    """
    p_shapes = api.param_shapes(cfg)
    p_sh = param_shardings(p_shapes, mesh)
    opt_sh = {"m": p_sh, "v": p_sh,
              "step": NamedSharding(mesh, P())}

    def loss_of(params, batch):
        return api.loss_fn(cfg, params, batch)

    def grads_of(params, batch):
        if grad_accum == 1:
            return jax.value_and_grad(loss_of)(params, batch)
        mb = _microbatch(batch, grad_accum)

        def body(acc, one):
            l, g = jax.value_and_grad(loss_of)(params, one)
            return (acc[0] + l,
                    jax.tree_util.tree_map(jnp.add, acc[1], g)), None

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (l_sum, g_sum), _ = jax.lax.scan(body, (jnp.zeros(()), zeros), mb)
        scale = 1.0 / grad_accum
        return l_sum * scale, jax.tree_util.tree_map(
            lambda g: g * scale, g_sum)

    if cross_pod == "compressed" and "pod" in mesh.axis_names:
        def step(params, opt_state, err, batch):
            def per_pod(params, err, batch):
                from repro.parallel.sharding import exclude_axes
                # 'pod' is manual inside this region — logical sharding
                # rules must not reference it
                with exclude_axes({"pod"}):
                    loss, grads = grads_of(params, batch)
                grads, err = compress.int8_psum_mean(grads, "pod", err)
                loss = jax.lax.pmean(loss, "pod")
                return loss, grads, err

            batch_specs = jax.tree_util.tree_map(
                lambda x: P("pod") if x.ndim >= 2 else P(), batch)
            loss, grads, err = compat.shard_map(
                per_pod, mesh=mesh,
                in_specs=(P(), P(), batch_specs),
                out_specs=(P(), P(), P()),
                axis_names={"pod"}, check_vma=False,
            )(params, err, batch)
            params, opt_state, gnorm = opt.update(grads, opt_state, params)
            return params, opt_state, err, {"loss": loss, "gnorm": gnorm}

        fn = jax.jit(
            step,
            donate_argnums=(0, 1, 2) if donate else (),
        )
        return fn, p_shapes, opt_sh

    def step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        params, opt_state, gnorm = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, "gnorm": gnorm}

    fn = jax.jit(
        step,
        in_shardings=(p_sh, opt_sh, None),
        out_shardings=(p_sh, opt_sh, None),
        donate_argnums=(0, 1) if donate else (),
    )
    return fn, p_shapes, opt_sh


# ---------------------------------------------------------------------------
# Host-side straggler monitor (multi-host concern; simulated/unit-tested).
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StragglerMonitor:
    """Tracks per-step wall times; flags steps slower than ``threshold`` ×
    the running median.  On a real deployment the flag feeds the coordination
    service (evict/replace the slow host, or skip its microbatch under
    bounded staleness); here it drives logging and is unit-tested with
    synthetic timings."""

    threshold: float = 3.0
    window: int = 32
    _times: list = dataclasses.field(default_factory=list)
    flagged: list = dataclasses.field(default_factory=list)

    def record(self, step: int, dt: float) -> bool:
        self._times.append(dt)
        hist = self._times[-self.window:]
        med = float(np.median(hist))
        slow = len(hist) >= 8 and dt > self.threshold * med
        if slow:
            self.flagged.append(step)
        return slow


# ---------------------------------------------------------------------------
# Training driver with checkpoint/restart.
# ---------------------------------------------------------------------------


def train(
    cfg: ModelConfig,
    mesh: Mesh,
    *,
    steps: int,
    batch_size: int,
    seq_len: int,
    ckpt_dir: str | None = None,
    ckpt_every: int = 50,
    resume: bool = True,
    lr: float = 1e-3,
    grad_accum: int = 1,
    seed: int = 0,
    log: Callable[[str], None] = print,
) -> dict:
    opt = AdamW(lr=lr)
    step_fn, p_shapes, _ = make_train_step(cfg, mesh, opt,
                                           grad_accum=grad_accum)
    stream = tokmod.TokenStream(cfg.vocab, seed=seed)
    monitor = StragglerMonitor()

    start = 0
    with use_mesh(mesh):
        if ckpt_dir and resume and (latest := ckpt.latest_step(ckpt_dir)) is not None:
            params, opt_state, meta = ckpt.restore(
                ckpt_dir, latest, mesh=mesh, abstract_params=p_shapes)
            start = meta["step"]
            log(f"resumed from checkpoint step {start}")
        else:
            params = api.init_params(cfg, jax.random.PRNGKey(seed))
            params = jax.device_put(params, param_shardings(p_shapes, mesh))
            opt_state = opt.init(params)

        losses = []
        for step in range(start, steps):
            host_batch = {"tokens": stream.batch(step, batch_size, seq_len)}
            extra = api.make_train_batch(cfg, batch_size, seq_len, seed=step)
            for k in extra:
                if k != "tokens":
                    host_batch[k] = np.asarray(extra[k])
            sh = _batch_shardings(cfg, mesh, {
                k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                for k, v in host_batch.items()})
            batch = {k: jax.device_put(v, sh[k]) for k, v in host_batch.items()}

            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            if monitor.record(step, dt):
                log(f"step {step}: straggler flagged ({dt:.2f}s)")
            losses.append(loss)
            if step % 10 == 0:
                log(f"step {step}: loss={loss:.4f} ({dt*1000:.0f} ms)")
            if ckpt_dir and (step + 1) % ckpt_every == 0:
                ckpt.save(ckpt_dir, step + 1, params, opt_state,
                          {"step": step + 1, "arch": cfg.arch_id})

        if ckpt_dir:
            ckpt.save(ckpt_dir, steps, params, opt_state,
                      {"step": steps, "arch": cfg.arch_id})
    return {"losses": losses, "params": params, "opt_state": opt_state,
            "straggler_flags": monitor.flagged}
