"""AdamW with global-norm clipping and cosine schedule — pure JAX, no
external deps.  Optimizer state is a params-shaped pytree (m, v) in fp32 and
inherits the parameters' FSDP sharding (ZeRO: the state lives wherever the
parameter shard lives)."""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp


def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params: Any) -> dict:
        zeros = lambda p: jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, jnp.float32), p)
        return {"m": zeros(params), "v": zeros(params),
                "step": jnp.zeros((), jnp.int32)}

    def update(self, grads: Any, state: dict, params: Any):
        step = state["step"] + 1
        lr = self.lr(step) if callable(self.lr) else self.lr

        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
        grads = jax.tree_util.tree_map(
            lambda g: g.astype(jnp.float32) * scale, grads)

        b1, b2 = self.b1, self.b2
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, m_, v_):
            u = (m_ / c1) / (jnp.sqrt(v_ / c2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, m, v)
        return new_params, {"m": m, "v": v, "step": step}, gnorm


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))
