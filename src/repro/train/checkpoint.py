"""Checkpointing: sharded-array save/restore with manifest, auto-resume and
elastic re-sharding.

Format: one directory per step —

    <dir>/step_<N>/arrays.npz      flattened path → array
    <dir>/step_<N>/manifest.json   step, arch, leaf inventory, written flag

Writes are atomic at the directory level (write to ``.tmp`` then rename), so
a crash mid-save never corrupts the latest checkpoint — the fault-tolerance
test kills a run between steps and restarts it bit-exactly.

Elasticity: arrays are stored logically (fully assembled); ``restore`` lays
them out on *any* mesh via ``device_put`` with the target sharding, so a job
checkpointed on 512 devices restarts on 256 (or 8) without conversion.  On a
real multi-host system assembly would stream through per-host shard files;
the manifest layout already carries per-leaf shape/dtype to support that.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.parallel.sharding import param_shardings


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten_like(tree_like: Any, arrays: dict[str, np.ndarray]) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path, like in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        a = arrays[key]
        assert tuple(a.shape) == tuple(like.shape), (key, a.shape, like.shape)
        leaves.append(a.astype(like.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save(dir_: str, step: int, params: Any, opt_state: Any,
         metadata: dict) -> str:
    final = os.path.join(dir_, f"step_{step}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    arrays = {f"params/{k}": v for k, v in _flatten(params).items()}
    arrays.update({f"opt/{k}": v for k, v in _flatten(opt_state).items()})
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = dict(metadata)
    manifest["leaves"] = {k: [list(v.shape), str(v.dtype)]
                          for k, v in arrays.items()}
    manifest["complete"] = True
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(dir_: str) -> int | None:
    if not os.path.isdir(dir_):
        return None
    steps = []
    for name in os.listdir(dir_):
        if name.startswith("step_") and not name.endswith(".tmp"):
            mf = os.path.join(dir_, name, "manifest.json")
            if os.path.exists(mf):
                with open(mf) as f:
                    if json.load(f).get("complete"):
                        steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(dir_: str, step: int, *, mesh: Mesh | None = None,
            abstract_params: Any = None) -> tuple[Any, Any, dict]:
    """Returns (params, opt_state, metadata).  With `mesh` +
    `abstract_params`, parameters and optimizer state are placed with the
    target mesh's shardings (elastic restart)."""
    path = os.path.join(dir_, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    p_arr = {k[len("params/"):]: data[k] for k in data.files
             if k.startswith("params/")}
    o_arr = {k[len("opt/"):]: data[k] for k in data.files
             if k.startswith("opt/")}

    if abstract_params is not None:
        params = _unflatten_like(abstract_params, p_arr)
        opt_like = {
            "m": jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                abstract_params),
            "v": jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                abstract_params),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
        opt_state = _unflatten_like(opt_like, o_arr)
    else:  # raw dicts
        params, opt_state = p_arr, o_arr

    if mesh is not None and abstract_params is not None:
        p_sh = param_shardings(abstract_params, mesh)
        params = jax.device_put(params, p_sh)
        opt_state = {
            "m": jax.device_put(opt_state["m"], p_sh),
            "v": jax.device_put(opt_state["v"], p_sh),
            "step": jax.device_put(opt_state["step"]),
        }
    return params, opt_state, meta
