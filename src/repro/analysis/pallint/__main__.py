import sys

from repro.analysis.pallint.cli import main

sys.exit(main())
