"""pallint core: findings, the rule registry, suppressions, and the driver.

pallint enforces the *hot-path doctrine* this codebase was built around
(DESIGN.md Sec 10): the steady-state query loop must stay device-resident —
no per-batch host syncs, no silent recompiles, no un-donated steady-state
buffers, no host↔device metadata bounces.  The paper's central claim
(broadcast beats subtree partitioning because communication never dominates)
dies by a thousand cuts otherwise, and PrIM-style benchmarking shows such
regressions are exactly the kind that go unnoticed.

Three rule families share this driver:

* ``PL1xx`` — AST doctrine rules over every Python file (rules.py).
* ``PC2xx`` — Pallas contract rules over every ``pl.pallas_call`` site
  (contracts.py).
* ``GR3xx`` — runtime guard violations (guards.py); surfaced through the
  same Finding type so the CLI/pytest plumbing is uniform.

Suppression: a line comment ``# pallint: disable=PL102`` (comma-separated
IDs, or ``disable=all``) suppresses findings reported *on that line*.  A
suppression at the top of a file (before any code, i.e. attached to line 1
via a module-level comment ``# pallint-file: disable=...``) suppresses for
the whole file.  Suppressions are the sanctioned-exception mechanism — e.g.
the single end-of-set sync in ``engine.stream_batches``.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
from typing import Callable, Iterable, Sequence

SUPPRESS_LINE_RE = re.compile(r"#\s*pallint:\s*disable=([A-Za-z0-9,_ ]+|all)")
SUPPRESS_FILE_RE = re.compile(r"#\s*pallint-file:\s*disable=([A-Za-z0-9,_ ]+|all)")

# Rule scopes: which part of the tree a rule patrols.  "src" rules guard
# library code only (tests and benchmarks legitimately sync, time, and
# catch broadly); "all" rules apply everywhere pallint walks.
SCOPE_SRC = "src"
SCOPE_ALL = "all"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One doctrine violation: rule ID, location, and a human message."""

    rule: str
    path: str
    line: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Rule:
    """A registered rule: ID, scope, one-line doctrine, and a checker.

    ``check(tree, src, path)`` yields Findings; suppression filtering is the
    driver's job, not the rule's.
    """

    rule_id: str
    scope: str
    doctrine: str
    check: Callable[[ast.AST, str, str], Iterable[Finding]]


_REGISTRY: dict[str, Rule] = {}


def register(rule_id: str, scope: str, doctrine: str):
    """Decorator registering ``fn(tree, src, path) -> Iterable[Finding]``."""

    def deco(fn):
        if rule_id in _REGISTRY:
            raise ValueError(f"duplicate pallint rule {rule_id}")
        _REGISTRY[rule_id] = Rule(rule_id, scope, doctrine, fn)
        return fn

    return deco


def registry() -> dict[str, Rule]:
    """All registered rules (importing the rule modules as a side effect)."""
    from repro.analysis.pallint import contracts, rules  # noqa: F401

    return dict(_REGISTRY)


def _suppressed(src: str) -> tuple[set[str] | None, dict[int, set[str] | None]]:
    """Parse suppression comments.

    Returns ``(file_level, per_line)`` where each value is a set of rule IDs
    or ``None`` meaning *all rules*.
    """
    file_level: set[str] | None = set()
    per_line: dict[int, set[str] | None] = {}
    for lineno, line in enumerate(src.splitlines(), start=1):
        m = SUPPRESS_FILE_RE.search(line)
        if m:
            ids = m.group(1).strip()
            if ids == "all":
                file_level = None
            elif file_level is not None:
                file_level |= {s.strip() for s in ids.split(",") if s.strip()}
        m = SUPPRESS_LINE_RE.search(line)
        if m:
            ids = m.group(1).strip()
            if ids == "all":
                per_line[lineno] = None
            else:
                cur = per_line.setdefault(lineno, set())
                if cur is not None:
                    cur |= {s.strip() for s in ids.split(",") if s.strip()}
    return (file_level if file_level else set()), per_line


def _is_suppressed(f: Finding, file_level, per_line) -> bool:
    if file_level is None or f.rule in (file_level or ()):
        return True
    if f.line in per_line:
        ids = per_line[f.line]
        return ids is None or f.rule in ids
    return False


def _in_src_scope(path: str) -> bool:
    parts = os.path.normpath(path).split(os.sep)
    return "src" in parts or ("repro" in parts and "tests" not in parts
                              and "benchmarks" not in parts)


def lint_file(path: str, rules: dict[str, Rule] | None = None,
              src: str | None = None) -> list[Finding]:
    """Lint one file; returns unsuppressed findings sorted by line."""
    rules = rules if rules is not None else registry()
    if src is None:
        with open(path, "r", encoding="utf-8") as fh:
            src = fh.read()
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [Finding("PL000", path, e.lineno or 1,
                        f"syntax error: {e.msg}")]
    file_level, per_line = _suppressed(src)
    in_src = _in_src_scope(path)
    out: list[Finding] = []
    for rule in rules.values():
        if rule.scope == SCOPE_SRC and not in_src:
            continue
        for f in rule.check(tree, src, path):
            if not _is_suppressed(f, file_level, per_line):
                out.append(f)
    return sorted(out, key=lambda f: (f.line, f.rule))


def walk_python_files(paths: Sequence[str]) -> list[str]:
    out = []
    for p in paths:
        if os.path.isfile(p) and p.endswith(".py"):
            out.append(p)
        elif os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = [d for d in dirs
                           if d not in ("__pycache__", ".git", ".pytest_cache")]
                out.extend(os.path.join(root, f)
                           for f in files if f.endswith(".py"))
    return sorted(out)


def lint_paths(paths: Sequence[str]) -> list[Finding]:
    rules = registry()
    findings: list[Finding] = []
    for path in walk_python_files(paths):
        findings.extend(lint_file(path, rules))
    return findings


def render_human(findings: Sequence[Finding], rules: dict[str, Rule]) -> str:
    lines = [f.format() for f in findings]
    seen = sorted({f.rule for f in findings})
    for rid in seen:
        if rid in rules:
            lines.append(f"  {rid}: {rules[rid].doctrine}")
    lines.append(f"pallint: {len(findings)} finding(s)")
    return "\n".join(lines)


def render_json(findings: Sequence[Finding]) -> str:
    return json.dumps({"findings": [f.to_json() for f in findings],
                       "count": len(findings)}, indent=2)
