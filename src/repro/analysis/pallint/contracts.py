"""pallint Pallas contract rules (PC2xx): static validation of every
``pl.pallas_call`` site.

The kernels are exact-int reproductions of the paper's DPU scan; their
BlockSpec plumbing is where silent corruption hides (an index map that walks
off the operand, a grid extent that silently truncates a non-divisible
shape, a kernel signature drifting out of sync with its specs).  These
contracts are checkable from the AST because this codebase's doctrine keeps
pallas_call sites literal: tuple-literal grids and block shapes, lambda
index maps, specs built in the same function.

PC201 index-map-arity      every BlockSpec index map takes exactly
                           ``len(grid) + num_scalar_prefetch`` arguments.
PC202 index-map-form       index maps return a tuple with one element per
                           block dimension; each element is a constant, a
                           grid variable, or a prefetch-table lookup
                           (``tid[i, j]``) — anything else cannot be bounds-
                           checked against the grid and is rejected.
PC203 kernel-signature     the kernel function takes exactly
                           ``num_scalar_prefetch + len(in_specs) +
                           len(out_specs)`` refs; the call site passes
                           ``num_scalar_prefetch + len(in_specs)`` operands;
                           out_specs block rank matches out_shape rank.
PC204 tile-divisibility    a grid extent computed as ``X // t`` requires an
                           ``assert X % t == 0`` guard in the same function
                           — otherwise a non-divisible operand silently
                           drops its tail tile.
PC205 interpret-twin       every kernel wrapper (function containing a
                           pallas_call) is exercised by name from the test
                           suite (the interpret-mode reference-twin tests);
                           reported by the cross-file coverage pass.
"""
from __future__ import annotations

import ast
import re

from repro.analysis.pallint.core import (
    SCOPE_ALL, Finding, register, walk_python_files)
from repro.analysis.pallint.rules import ModuleInfo, dotted


class PallasSite:
    """One parsed ``pl.pallas_call(...)`` site."""

    def __init__(self, info: ModuleInfo, call: ast.Call):
        self.info = info
        self.call = call
        self.line = call.lineno
        self.kernel_name = (call.args[0].id
                            if call.args and isinstance(call.args[0], ast.Name)
                            else None)
        self.enclosing = info.enclosing_function(call)
        kw = {k.arg: k.value for k in call.keywords}
        self.num_prefetch = 0
        grid_src = kw
        if "grid_spec" in kw:
            spec_call = self._resolve_grid_spec(kw["grid_spec"])
            if spec_call is not None:
                grid_src = {k.arg: k.value for k in spec_call.keywords}
                np_node = grid_src.get("num_scalar_prefetch")
                if isinstance(np_node, ast.Constant):
                    self.num_prefetch = int(np_node.value)
        self.grid = grid_src.get("grid")
        self.in_specs = grid_src.get("in_specs")
        self.out_specs = grid_src.get("out_specs")
        self.out_shape = kw.get("out_shape")
        # operand list: the pallas_call result is immediately applied
        parent = info._parents.get(call)
        self.operands = (parent.args
                         if isinstance(parent, ast.Call)
                         and parent.func is call else None)

    def _resolve_grid_spec(self, node: ast.AST) -> ast.Call | None:
        if isinstance(node, ast.Call):
            return node
        if isinstance(node, ast.Name) and self.enclosing is not None:
            for stmt in ast.walk(self.enclosing):
                if (isinstance(stmt, ast.Assign)
                        and isinstance(stmt.value, ast.Call)
                        and any(isinstance(t, ast.Name) and t.id == node.id
                                for t in stmt.targets)):
                    d = dotted(stmt.value.func, self.info.aliases) or ""
                    if d.endswith("GridSpec"):
                        return stmt.value
        return None

    @property
    def grid_len(self) -> int | None:
        if isinstance(self.grid, ast.Tuple):
            return len(self.grid.elts)
        return None

    def block_specs(self) -> list[tuple[ast.Call, str]]:
        """All BlockSpec constructor calls at this site, tagged in/out."""
        out = []
        if isinstance(self.in_specs, (ast.List, ast.Tuple)):
            for el in self.in_specs.elts:
                if isinstance(el, ast.Call):
                    out.append((el, "in"))
        if isinstance(self.out_specs, ast.Call):
            out.append((self.out_specs, "out"))
        elif isinstance(self.out_specs, (ast.List, ast.Tuple)):
            for el in self.out_specs.elts:
                if isinstance(el, ast.Call):
                    out.append((el, "out"))
        return out

    @property
    def n_in(self) -> int | None:
        if isinstance(self.in_specs, (ast.List, ast.Tuple)):
            return len(self.in_specs.elts)
        return None

    @property
    def n_out(self) -> int:
        if isinstance(self.out_specs, (ast.List, ast.Tuple)):
            return len(self.out_specs.elts)
        return 1


def find_sites(info: ModuleInfo) -> list[PallasSite]:
    sites = []
    for node in ast.walk(info.tree):
        if isinstance(node, ast.Call):
            d = dotted(node.func, info.aliases) or ""
            if d.endswith("pallas_call"):
                sites.append(PallasSite(info, node))
    return sites


def _block_shape(spec_call: ast.Call) -> ast.Tuple | None:
    if spec_call.args and isinstance(spec_call.args[0], ast.Tuple):
        return spec_call.args[0]
    for k in spec_call.keywords:
        if k.arg == "block_shape" and isinstance(k.value, ast.Tuple):
            return k.value
    return None


def _index_map(spec_call: ast.Call) -> ast.Lambda | None:
    for node in list(spec_call.args) + [k.value for k in spec_call.keywords]:
        if isinstance(node, ast.Lambda):
            return node
    return None


@register("PC201", SCOPE_ALL,
          "BlockSpec index map arity must equal len(grid) plus the number "
          "of scalar-prefetch operands")
def check_index_map_arity(tree, src, path):
    info = ModuleInfo(tree)
    for site in find_sites(info):
        want = site.grid_len
        if want is None:
            continue
        want += site.num_prefetch
        for spec, kind in site.block_specs():
            lam = _index_map(spec)
            if lam is None:
                continue
            got = len(lam.args.args)
            if got != want:
                yield Finding(
                    "PC201", path, spec.lineno,
                    f"{kind}-spec index map takes {got} args, grid+prefetch "
                    f"needs {want}")


@register("PC202", SCOPE_ALL,
          "index maps must return one element per block dim, each a "
          "constant, grid variable, or prefetch lookup")
def check_index_map_form(tree, src, path):
    info = ModuleInfo(tree)
    for site in find_sites(info):
        for spec, kind in site.block_specs():
            lam = _index_map(spec)
            if lam is None:
                continue
            params = {a.arg for a in lam.args.args}
            body = lam.body
            elements = body.elts if isinstance(body, ast.Tuple) else [body]
            shape = _block_shape(spec)
            if (shape is not None and isinstance(body, ast.Tuple)
                    and len(elements) != len(shape.elts)):
                yield Finding(
                    "PC202", path, spec.lineno,
                    f"{kind}-spec index map returns {len(elements)} "
                    f"indices for a rank-{len(shape.elts)} block")
                continue
            for el in elements:
                ok = (isinstance(el, ast.Constant)
                      or (isinstance(el, ast.Name) and el.id in params)
                      or (isinstance(el, ast.Subscript)
                          and isinstance(el.value, ast.Name)
                          and el.value.id in params))
                if not ok:
                    yield Finding(
                        "PC202", path, spec.lineno,
                        f"{kind}-spec index map element "
                        f"{ast.unparse(el)!r} is not a constant, grid "
                        "variable, or prefetch lookup")


@register("PC203", SCOPE_ALL,
          "kernel signature, spec counts, operand counts, and out_shape "
          "rank must agree")
def check_kernel_signature(tree, src, path):
    info = ModuleInfo(tree)
    fn_by_name = {f.name: f for f in info.functions}
    for site in find_sites(info):
        n_in = site.n_in
        if n_in is None:
            continue
        want_refs = site.num_prefetch + n_in + site.n_out
        kernel = fn_by_name.get(site.kernel_name or "")
        if kernel is not None:
            got = len(kernel.args.args)
            if got != want_refs:
                yield Finding(
                    "PC203", path, site.line,
                    f"kernel {site.kernel_name!r} takes {got} refs; "
                    f"prefetch({site.num_prefetch}) + in({n_in}) + "
                    f"out({site.n_out}) = {want_refs}")
        if site.operands is not None:
            want_ops = site.num_prefetch + n_in
            if len(site.operands) != want_ops:
                yield Finding(
                    "PC203", path, site.line,
                    f"call passes {len(site.operands)} operands; specs "
                    f"declare {want_ops}")
        # out_shape rank vs out-spec block rank
        if (isinstance(site.out_shape, ast.Call)
                and site.out_shape.args
                and isinstance(site.out_shape.args[0], ast.Tuple)
                and isinstance(site.out_specs, ast.Call)):
            shape_rank = len(site.out_shape.args[0].elts)
            block = _block_shape(site.out_specs)
            if block is not None and len(block.elts) != shape_rank:
                yield Finding(
                    "PC203", path, site.line,
                    f"out_shape rank {shape_rank} != out-spec block rank "
                    f"{len(block.elts)}")


def _floordiv_bindings(fn: ast.FunctionDef) -> dict[str, tuple[str, str]]:
    """Names bound as ``name = X // t`` (Names only) in ``fn``."""
    out: dict[str, tuple[str, str]] = {}

    def bind(target, value):
        if (isinstance(target, ast.Name) and isinstance(value, ast.BinOp)
                and isinstance(value.op, ast.FloorDiv)
                and isinstance(value.left, ast.Name)
                and isinstance(value.right, ast.Name)):
            out[target.id] = (value.left.id, value.right.id)

    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if (isinstance(tgt, ast.Tuple)
                        and isinstance(node.value, ast.Tuple)
                        and len(tgt.elts) == len(node.value.elts)):
                    for t, v in zip(tgt.elts, node.value.elts):
                        bind(t, v)
                else:
                    bind(tgt, node.value)
    return out


def _has_mod_guard(fn: ast.FunctionDef, num: str, den: str) -> bool:
    """True if ``fn`` asserts (or branches on) ``num % den == 0``."""
    for node in ast.walk(fn):
        if not isinstance(node, (ast.Assert, ast.If)):
            continue
        for sub in ast.walk(node.test):
            if (isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.Mod)
                    and isinstance(sub.left, ast.Name) and sub.left.id == num
                    and isinstance(sub.right, ast.Name)
                    and sub.right.id == den):
                return True
    return False


@register("PC204", SCOPE_ALL,
          "a grid extent of X // t needs an `assert X % t == 0` guard in "
          "the same function — non-divisible shapes silently drop a tile")
def check_tile_divisibility(tree, src, path):
    info = ModuleInfo(tree)
    for site in find_sites(info):
        if not isinstance(site.grid, ast.Tuple) or site.enclosing is None:
            continue
        bindings = _floordiv_bindings(site.enclosing)
        for el in site.grid.elts:
            if isinstance(el, ast.Name) and el.id in bindings:
                num, den = bindings[el.id]
                if not _has_mod_guard(site.enclosing, num, den):
                    yield Finding(
                        "PC204", path, site.line,
                        f"grid extent {el.id} = {num} // {den} without an "
                        f"`assert {num} % {den} == 0` guard")


# ---------------------------------------------------------------------------
# PC205: cross-file interpret-twin coverage (driven from the CLI).
# ---------------------------------------------------------------------------


def kernel_wrappers(src_paths) -> list[tuple[str, str, int]]:
    """(wrapper_name, path, line) for every function containing a
    pallas_call in ``src_paths``."""
    out = []
    for path in walk_python_files(src_paths):
        try:
            with open(path, "r", encoding="utf-8") as fh:
                source = fh.read()
            tree = ast.parse(source, filename=path)
        except (OSError, SyntaxError):
            continue
        info = ModuleInfo(tree)
        seen = set()
        for site in find_sites(info):
            fn = site.enclosing
            if fn is not None and fn.name not in seen:
                seen.add(fn.name)
                out.append((fn.name, path, fn.lineno))
    return out


def coverage_findings(src_paths, test_paths) -> list[Finding]:
    """PC205: kernel wrappers never referenced from the test suite."""
    wrappers = kernel_wrappers(src_paths)
    test_blob = []
    for path in walk_python_files(test_paths):
        try:
            with open(path, "r", encoding="utf-8") as fh:
                test_blob.append(fh.read())
        except OSError:
            continue
    blob = "\n".join(test_blob)
    findings = []
    for name, path, line in wrappers:
        if not re.search(rf"\b{re.escape(name)}\b", blob):
            findings.append(Finding(
                "PC205", path, line,
                f"kernel wrapper {name!r} has no interpret-mode "
                "reference-twin test"))
    return findings


def coverage_report(src_paths, test_paths) -> dict:
    """Machine-readable coverage map (consumed by the twin-test suite)."""
    wrappers = kernel_wrappers(src_paths)
    missing = {f.message.split("'")[1] for f in
               coverage_findings(src_paths, test_paths)}
    return {
        "kernel_wrappers": [
            {"name": n, "path": p, "line": ln, "covered": n not in missing}
            for n, p, ln in wrappers],
        "missing": sorted(missing),
    }
