"""pallint AST rules (PL1xx): the hot-path doctrine, machine-checked.

Rule catalog (DESIGN.md Sec 10):

PL101 host-sync-in-jit        no ``np.asarray``/``np.array``/``.item()``/
                              ``float()``/``jax.device_get``/
                              ``block_until_ready`` inside jit-compiled or
                              kernel-adjacent functions.
PL102 stray-host-sync         ``block_until_ready`` in library code outside
                              the sanctioned end-of-set sync (inline
                              suppression marks the sanctioned site).
PL103 python-loop-over-device Python ``for`` loops iterating a device array.
PL104 undeclared-donation     jitted steady-state step builders
                              (``make_*step``) must *declare*
                              ``donate_argnums`` — an explicit ``()`` is an
                              audited opt-out, absence is a doctrine hole.
PL105 dynamic-shape-hazard    ``jnp`` array constructors whose shape/size
                              arguments are freshly unboxed Python scalars
                              (``int()``/``float()``/``.item()``) — every
                              distinct value recompiles the trace.
PL106 mutable-default-arg     mutable default arguments in library code.
PL107 bare-except             bare ``except:`` in library code.
PL108 device-host-bounce      ``np.asarray(...)`` over an expression that
                              itself builds a device array (``jnp.*``) — a
                              host→device→host round trip.
PL109 int64-index-dtype       explicit ``int64`` dtypes in library code;
                              coordinates and indices are int32 by doctrine
                              (32-bit index-dtype consistency; suppress for
                              genuine 64-bit payloads such as byte counters).
PL110 unbounded-serve-loop    in serving code (``repro/serve/``): ``while
                              True`` loops with no exit at all, and
                              except-and-continue retry patterns inside a
                              constant-true loop.  Retries must carry a
                              deadline or attempt bound (``for attempt in
                              range(n)``, a watchdog, or a real loop
                              condition) — an always-on serving loop must
                              shed or degrade, never hang.
PL111 hot-path-wall-clock-io  in hot-path modules (``repro/core/``,
                              ``repro/serve/``, ``repro/kernels/``): no
                              direct ``time.time()`` (wall clock drifts and
                              jumps; timing goes through ``time.monotonic*``
                              or the ``repro.obs`` tracer) and no ``print()``
                              (output goes through metrics/trace, never
                              stdout on the hot path).
PL113 candidate-mask-d2h      in query modules (``repro/**/query/``): no
                              host materialization (``np.asarray``/
                              ``np.array``/``jax.device_get``) of a device
                              comparison/mask expression.  A ``(Q, R)`` or
                              ``(Q, Kcap)``-bool candidate mask pulled to the
                              host scales with the *corpus*, not the answer —
                              results cross the boundary only as fixed-size
                              ``(Q, Kcap)`` ID buffers or per-query scalars.
PL112 silent-failover         in serving code (``repro/serve/``): an
                              ``except`` handler that reroutes work
                              (``submit``/``resubmit``/``reroute``/
                              ``failover`` call) without recording the event
                              (a counter ``.inc``, a trace ``event``, or a
                              ``_record_*`` helper).  Failover that leaves
                              no metric/span behind turns a degraded fleet
                              into an invisible one — every reroute must hit
                              ``router_failovers_total`` or equivalent.

Detection of "jit-compiled or kernel-adjacent" (PL101): a function is a jit
context if (a) a decorator references ``jit``, (b) its name is passed as the
first positional argument to ``jax.jit`` / ``shard_map`` / ``pallas_call``
anywhere in the module, (c) its name ends in ``_kernel``, or (d) it is
nested inside a jit context (e.g. ``@pl.when`` bodies inside a kernel).
"""
from __future__ import annotations

import ast
import os
import re

from repro.analysis.pallint.core import (
    SCOPE_ALL, SCOPE_SRC, Finding, register)

STEP_BUILDER_RE = re.compile(r"^make_\w*step$")

_JNP_CONSTRUCTORS = {
    "zeros", "ones", "full", "empty", "arange", "linspace", "eye",
    "tile", "broadcast_to", "reshape", "iota",
}


def resolve_aliases(tree: ast.AST) -> dict[str, str]:
    """Map imported names to canonical dotted module paths.

    ``import jax.numpy as jnp`` → ``{"jnp": "jax.numpy"}``;
    ``from jax.experimental import pallas as pl`` →
    ``{"pl": "jax.experimental.pallas"}``; ``import jax`` → ``{"jax": "jax"}``.
    """
    aliases: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def dotted(node: ast.AST, aliases: dict[str, str]) -> str | None:
    """Canonical dotted path of a Name/Attribute chain, alias-resolved."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(aliases.get(node.id, node.id))
    return ".".join(reversed(parts))


def _first_positional_name(call: ast.Call) -> str | None:
    if call.args and isinstance(call.args[0], ast.Name):
        return call.args[0].id
    return None


class ModuleInfo:
    """Shared per-module analysis: aliases, function table, jit contexts."""

    def __init__(self, tree: ast.AST):
        self.tree = tree
        self.aliases = resolve_aliases(tree)
        self.functions: list[ast.FunctionDef] = [
            n for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        ]
        self._parents: dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(tree):
            for child in ast.iter_child_nodes(parent):
                self._parents[child] = parent
        self.jit_context_fns = self._find_jit_contexts()

    def parent_chain(self, node: ast.AST):
        cur = self._parents.get(node)
        while cur is not None:
            yield cur
            cur = self._parents.get(cur)

    def enclosing_function(self, node: ast.AST) -> ast.FunctionDef | None:
        for p in self.parent_chain(node):
            if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return p
        return None

    def _decorated_jit(self, fn: ast.FunctionDef) -> bool:
        for dec in fn.decorator_list:
            for sub in ast.walk(dec):
                d = dotted(sub, self.aliases)
                if d and (d.endswith(".jit") or d == "jit"):
                    return True
        return False

    def _find_jit_contexts(self) -> set[ast.FunctionDef]:
        # names handed to jit/shard_map/pallas_call as the traced callable
        traced_names: set[str] = set()
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call):
                d = dotted(node.func, self.aliases) or ""
                if (d.endswith(".jit") or d == "jit"
                        or d.endswith("shard_map")
                        or d.endswith("pallas_call")):
                    name = _first_positional_name(node)
                    if name:
                        traced_names.add(name)
        ctx: set[ast.FunctionDef] = set()
        for fn in self.functions:
            if (self._decorated_jit(fn) or fn.name in traced_names
                    or fn.name.endswith("_kernel")):
                ctx.add(fn)
        # nested defs inherit their enclosing jit context
        changed = True
        while changed:
            changed = False
            for fn in self.functions:
                if fn in ctx:
                    continue
                enc = self.enclosing_function(fn)
                if enc is not None and enc in ctx:
                    ctx.add(fn)
                    changed = True
        return ctx

    def in_jit_context(self, node: ast.AST) -> bool:
        fn = self.enclosing_function(node)
        return fn is not None and fn in self.jit_context_fns

    def contains_jnp(self, node: ast.AST) -> bool:
        for sub in ast.walk(node):
            d = dotted(sub, self.aliases)
            if d and (d.startswith("jax.numpy") or d.startswith("jax.lax")):
                return True
        return False


_HOST_SYNC_FUNCS = {"numpy.asarray", "numpy.array", "jax.device_get"}


@register("PL101", SCOPE_ALL,
          "host sync / host materialization inside a jit-compiled or "
          "kernel-adjacent function breaks the device-resident hot path")
def check_host_sync_in_jit(tree, src, path):
    info = ModuleInfo(tree)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call) or not info.in_jit_context(node):
            continue
        d = dotted(node.func, info.aliases)
        msg = None
        if d in _HOST_SYNC_FUNCS:
            msg = f"call to {d.replace('numpy', 'np')} in jit context"
        elif d == "float" and node.args and not isinstance(
                node.args[0], ast.Constant):
            msg = "float() unboxing in jit context"
        elif (isinstance(node.func, ast.Attribute)
              and node.func.attr in ("item", "block_until_ready")):
            msg = f".{node.func.attr}() host sync in jit context"
        if msg:
            yield Finding("PL101", path, node.lineno, msg)


@register("PL102", SCOPE_SRC,
          "block_until_ready in library code — the hot path allows exactly "
          "one sanctioned end-of-set sync (inline-suppressed at its site)")
def check_stray_host_sync(tree, src, path):
    info = ModuleInfo(tree)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        is_bur = (isinstance(node.func, ast.Attribute)
                  and node.func.attr == "block_until_ready")
        if is_bur and not info.in_jit_context(node):
            yield Finding("PL102", path, node.lineno,
                          "block_until_ready outside the sanctioned sync")


@register("PL103", SCOPE_ALL,
          "Python for-loop over a device array executes one dispatch per "
          "element — use vectorized ops or lax control flow")
def check_loop_over_device_array(tree, src, path):
    info = ModuleInfo(tree)
    # names bound (anywhere in the module) from a jnp-producing expression
    jnp_names: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and info.contains_jnp(node.value):
            for tgt in node.targets:
                for sub in ast.walk(tgt):
                    if isinstance(sub, ast.Name):
                        jnp_names.add(sub.id)
    for node in ast.walk(tree):
        if not isinstance(node, ast.For):
            continue
        it = node.iter
        if isinstance(it, ast.Call) and info.contains_jnp(it.func):
            yield Finding("PL103", path, node.lineno,
                          "for-loop over a jnp call result")
        elif isinstance(it, ast.Name) and it.id in jnp_names:
            yield Finding("PL103", path, node.lineno,
                          f"for-loop over device array {it.id!r}")


@register("PL104", SCOPE_SRC,
          "steady-state jitted step builders must declare donate_argnums "
          "(an explicit empty tuple is an audited opt-out)")
def check_undeclared_donation(tree, src, path):
    info = ModuleInfo(tree)
    for fn in info.functions:
        if not STEP_BUILDER_RE.match(fn.name):
            continue
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            d = dotted(node.func, info.aliases) or ""
            if not (d.endswith(".jit") or d == "jit"):
                continue
            kw = {k.arg for k in node.keywords}
            if "donate_argnums" not in kw and "donate_argnames" not in kw:
                yield Finding(
                    "PL104", path, node.lineno,
                    f"jax.jit in step builder {fn.name!r} without a "
                    "donate_argnums declaration")


def _unboxing_calls(node: ast.AST, aliases) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            if isinstance(sub.func, ast.Name) and sub.func.id in (
                    "int", "float"):
                if sub.args and not isinstance(sub.args[0], ast.Constant):
                    return True
            elif (isinstance(sub.func, ast.Attribute)
                  and sub.func.attr == "item"):
                return True
    return False


@register("PL105", SCOPE_ALL,
          "jnp constructor shaped by a freshly unboxed Python scalar — "
          "every distinct value triggers a recompile")
def check_dynamic_shape_hazard(tree, src, path):
    info = ModuleInfo(tree)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        d = dotted(node.func, info.aliases) or ""
        if not d.startswith("jax.numpy."):
            continue
        if d.rsplit(".", 1)[-1] not in _JNP_CONSTRUCTORS:
            continue
        for arg in list(node.args) + [k.value for k in node.keywords
                                      if k.arg in (None, "shape")]:
            if _unboxing_calls(arg, info.aliases):
                yield Finding(
                    "PL105", path, node.lineno,
                    f"{d.replace('jax.numpy', 'jnp')} shaped by "
                    "int()/float()/.item() — recompilation hazard")
                break


@register("PL106", SCOPE_SRC,
          "mutable default argument — shared across calls")
def check_mutable_default(tree, src, path):
    info = ModuleInfo(tree)
    for fn in info.functions:
        for default in list(fn.args.defaults) + [
                d for d in fn.args.kw_defaults if d is not None]:
            bad = isinstance(default, (ast.List, ast.Dict, ast.Set))
            if isinstance(default, ast.Call):
                d = dotted(default.func, info.aliases) or ""
                bad = d in ("list", "dict", "set") or d.endswith(
                    (".array", ".zeros", ".ones", ".empty"))
            if bad:
                yield Finding("PL106", path, default.lineno,
                              f"mutable default in {fn.name!r}")


@register("PL107", SCOPE_SRC,
          "bare except swallows every error including guard violations")
def check_bare_except(tree, src, path):
    for node in ast.walk(tree):
        if isinstance(node, ast.ExceptHandler) and node.type is None:
            yield Finding("PL107", path, node.lineno, "bare except:")


@register("PL108", SCOPE_SRC,
          "np.asarray over a jnp-built value is a host→device→host bounce — "
          "compute on one side of the boundary")
def check_device_host_bounce(tree, src, path):
    info = ModuleInfo(tree)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        d = dotted(node.func, info.aliases)
        if d not in ("numpy.asarray", "numpy.array"):
            continue
        if any(info.contains_jnp(a) for a in node.args):
            yield Finding("PL108", path, node.lineno,
                          "np.asarray over a jnp expression (device→host "
                          "bounce)")


def _const_true(test: ast.AST) -> bool:
    return isinstance(test, ast.Constant) and bool(test.value)


@register("PL110", SCOPE_SRC,
          "serving loops must be bounded: no exit-free while-True and no "
          "except-and-continue retry without a deadline/attempt bound")
def check_unbounded_serve_loop(tree, src, path):
    parts = os.path.normpath(path).split(os.sep)
    if "serve" not in parts:
        return
    info = ModuleInfo(tree)
    for node in ast.walk(tree):
        if isinstance(node, ast.While) and _const_true(node.test):
            exits = any(isinstance(sub, (ast.Break, ast.Return, ast.Raise))
                        for sub in ast.walk(node))
            if not exits:
                yield Finding(
                    "PL110", path, node.lineno,
                    "while True with no break/return/raise — an unbounded "
                    "serving loop can never shed or degrade")
        elif (isinstance(node, ast.ExceptHandler) and node.body
                and isinstance(node.body[-1], ast.Continue)):
            for parent in info.parent_chain(node):
                if isinstance(parent, (ast.For, ast.AsyncFor)):
                    break                       # bounded by the iterator
                if isinstance(parent, ast.While):
                    if _const_true(parent.test):
                        yield Finding(
                            "PL110", path, node.lineno,
                            "except-and-continue inside while True — retry "
                            "forever with no deadline/attempt bound")
                    break


@register("PL111", SCOPE_SRC,
          "hot-path modules (core/serve/kernels) must not call time.time() "
          "or print() directly — use monotonic clocks and the obs layer")
def check_hot_path_wall_clock_io(tree, src, path):
    parts = os.path.normpath(path).split(os.sep)
    if not any(p in ("core", "serve", "kernels") for p in parts):
        return
    info = ModuleInfo(tree)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        d = dotted(node.func, info.aliases)
        if d == "time.time":
            yield Finding(
                "PL111", path, node.lineno,
                "time.time() in a hot-path module — the wall clock drifts "
                "and jumps; use time.monotonic()/monotonic_ns() or the "
                "repro.obs tracer")
        elif isinstance(node.func, ast.Name) and node.func.id == "print":
            yield Finding(
                "PL111", path, node.lineno,
                "print() in a hot-path module — emit through repro.obs "
                "metrics/trace, never stdout on the hot path")


_REROUTE_NAMES = {"submit", "resubmit", "reroute", "failover"}


def _call_name(node: ast.Call) -> str | None:
    if isinstance(node.func, ast.Attribute):
        return node.func.attr
    if isinstance(node.func, ast.Name):
        return node.func.id
    return None


@register("PL112", SCOPE_SRC,
          "failover must be observable: an except handler that reroutes "
          "(submit/reroute/failover) must also record it (counter inc, "
          "trace event, or a _record_* helper)")
def check_silent_failover(tree, src, path):
    parts = os.path.normpath(path).split(os.sep)
    if "serve" not in parts:
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        names = {n for n in (
            _call_name(sub) for sub in ast.walk(node)
            if isinstance(sub, ast.Call)) if n}
        reroutes = names & _REROUTE_NAMES
        if not reroutes:
            continue
        recorded = any(n == "inc" or n == "event" or n.startswith("_record")
                       for n in names)
        if not recorded:
            yield Finding(
                "PL112", path, node.lineno,
                f"except handler reroutes ({sorted(reroutes)[0]}) without "
                "recording the failover — increment a failover counter or "
                "emit a trace event inside the handler")


_MASK_BUILDERS = {
    "logical_and", "logical_or", "logical_xor", "logical_not",
    "less", "less_equal", "greater", "greater_equal", "equal", "not_equal",
    "isin", "isclose",
}


def _contains_device_mask(node: ast.AST, info: ModuleInfo) -> bool:
    """True if the subtree builds a boolean mask out of device arrays:
    a comparison / bitwise-bool combine / jnp mask builder over jnp
    operands."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Compare) and info.contains_jnp(sub):
            return True
        if (isinstance(sub, ast.BinOp)
                and isinstance(sub.op, (ast.BitAnd, ast.BitOr, ast.BitXor))
                and info.contains_jnp(sub)):
            return True
        if isinstance(sub, ast.UnaryOp) and isinstance(sub.op, ast.Invert) \
                and info.contains_jnp(sub):
            return True
        if isinstance(sub, ast.Call):
            d = dotted(sub.func, info.aliases) or ""
            if (d.startswith("jax.numpy.")
                    and d.rsplit(".", 1)[-1] in _MASK_BUILDERS):
                return True
    return False


@register("PL113", SCOPE_SRC,
          "host materialization of a device candidate mask in query code — "
          "candidate sets stay on-fabric; only fixed-size (Q, Kcap) ID "
          "buffers or per-query scalars cross the boundary")
def check_candidate_mask_d2h(tree, src, path):
    parts = os.path.normpath(path).split(os.sep)
    if "query" not in parts:
        return
    info = ModuleInfo(tree)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        d = dotted(node.func, info.aliases)
        if d not in ("numpy.asarray", "numpy.array", "jax.device_get"):
            continue
        if any(_contains_device_mask(a, info) for a in node.args):
            yield Finding(
                "PL113", path, node.lineno,
                f"{d.replace('numpy', 'np')} over a device mask expression "
                "— a host candidate list scales with the corpus, not the "
                "answer; keep the mask on-fabric and materialize only the "
                "(Q, Kcap) ID buffer")


@register("PL109", SCOPE_SRC,
          "explicit int64 dtype in library code — indices and coordinates "
          "are int32 by doctrine (suppress for true 64-bit payloads)")
def check_int64_index_dtype(tree, src, path):
    info = ModuleInfo(tree)
    for node in ast.walk(tree):
        d = dotted(node, info.aliases)
        if d in ("numpy.int64", "jax.numpy.int64"):
            yield Finding("PL109", path, node.lineno,
                          f"explicit {d.split('.')[0]}.int64 dtype")
