"""pallint CLI.

Usage::

    python -m repro.analysis.pallint src tests benchmarks
    python -m repro.analysis.pallint src --json
    python -m repro.analysis.pallint --guards
    python -m repro.analysis.pallint --list-rules

Exit status 0 when the tree is doctrine-clean, 1 when any finding is
reported (each with its rule ID and location), 2 on usage errors.

When the path list contains both library code and a test tree, the PC205
interpret-twin coverage pass runs across them; ``--guards`` additionally
drives the runtime trace-guard self-check over the public jitted
entrypoints (slow: it builds tiny engines and compiles real steps).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.analysis.pallint import contracts
from repro.analysis.pallint.core import (
    lint_paths, registry, render_human, render_json)


def _split_paths(paths):
    """Partition into (library, tests) path groups for the coverage pass."""
    tests = [p for p in paths
             if os.path.basename(os.path.normpath(p)).startswith("test")
             or "tests" in os.path.normpath(p).split(os.sep)]
    lib = [p for p in paths if p not in tests]
    return lib, tests


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="pallint",
        description="device-residency lint + compile/transfer guard for the "
                    "repro hot path")
    parser.add_argument("paths", nargs="*", default=[],
                        help="files/directories to lint")
    parser.add_argument("--json", action="store_true",
                        help="machine-readable output")
    parser.add_argument("--guards", action="store_true",
                        help="run the runtime trace-guard self-check over "
                             "the public jitted entrypoints")
    parser.add_argument("--guard-only", action="append", default=None,
                        metavar="NAME",
                        help="restrict --guards to one entrypoint check")
    parser.add_argument("--no-coverage", action="store_true",
                        help="skip the PC205 interpret-twin coverage pass")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule catalog and exit")
    args = parser.parse_args(argv)

    rules = registry()
    if args.list_rules:
        for rid, rule in sorted(rules.items()):
            print(f"{rid} [{rule.scope}] {rule.doctrine}")
        return 0

    if not args.paths and not args.guards:
        parser.print_usage(sys.stderr)
        return 2

    findings = lint_paths(args.paths) if args.paths else []

    lib, tests = _split_paths(args.paths)
    coverage = None
    if lib and tests and not args.no_coverage:
        findings.extend(contracts.coverage_findings(lib, tests))
        coverage = contracts.coverage_report(lib, tests)

    if args.guards:
        from repro.analysis.pallint import guards
        findings.extend(guards.run_entrypoint_checks(args.guard_only))

    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    if args.json:
        payload = json.loads(render_json(findings))
        if coverage is not None:
            payload["coverage"] = coverage
        print(json.dumps(payload, indent=2))
    else:
        print(render_human(findings, rules))
    return 1 if findings else 0
