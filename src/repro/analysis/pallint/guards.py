"""pallint runtime trace guards (GR3xx): the dynamic half of the doctrine.

Static rules can't prove that a jitted entrypoint stays compiled-once and
device-resident at runtime — shape drift recompiles silently, and a stray
``np.asarray`` on a device value syncs the pipeline without any syntactic
tell at the call site.  This harness wraps steady-state execution in:

* ``jax.transfer_guard_device_to_host("disallow")`` — any *implicit*
  device→host transfer raises.  Explicit retrieval (``jax.device_get``) at
  the sanctioned end-of-set sync stays legal, which is exactly the doctrine:
  results leave the device once, on purpose, never as a side effect.
* compilation-count freezing — ``PjitFunction._cache_size()`` (or any
  user-supplied counter, e.g. an engine's ``trace_count``) is snapshotted
  before the steady-state region and must not grow.

Violations raise :class:`GuardViolation` carrying GR301 (recompile) or
GR302 (implicit transfer).  Exposed as a pytest fixture
(:mod:`repro.analysis.pallint.pytest_plugin`) and as the CLI self-check
(``python -m repro.analysis.pallint --guards``), which drives the public
jitted entrypoints — broadcast engine step, subtree engine step, and the
serve-loop decode step — through warmup + guarded steady state.

Every guarded region also exports into the process-default metrics registry
(:func:`repro.obs.metrics.get_registry`): ``pallint_recompiles_total`` /
``pallint_implicit_transfers_total`` count violations by ``where``, and
``pallint_compile_count{entrypoint=...}`` gauges the cached specialization
count observed on exit — so a scrape shows guard health alongside the
serving metrics without a second plumbing layer.
"""
from __future__ import annotations

import contextlib
from typing import Callable

import jax

from repro.analysis.pallint.core import Finding


def _guard_registry():
    """The default obs registry (lazy import: guards must not force obs)."""
    from repro.obs import metrics as obs_metrics
    return obs_metrics.get_registry()


class GuardViolation(AssertionError):
    """A hot-path doctrine violation observed at runtime."""

    def __init__(self, findings: list[Finding]):
        self.findings = findings
        super().__init__("\n".join(f.format() for f in findings))


def compile_count(fn) -> int | None:
    """Number of compiled specializations cached on a jitted callable."""
    cache_size = getattr(fn, "_cache_size", None)
    if callable(cache_size):
        return int(cache_size())
    return None


def _snapshot(counters: dict[str, Callable[[], int | None]]):
    return {name: get() for name, get in counters.items()}


def _normalize(entrypoints, counters):
    """Build name → count-getter from jitted fns and/or explicit counters."""
    out: dict[str, Callable[[], int | None]] = {}
    for name, fn in (entrypoints or {}).items():
        out[name] = (lambda f=fn: compile_count(f))
    for name, get in (counters or {}).items():
        out[name] = get
    return out


@contextlib.contextmanager
def steady_state(entrypoints: dict[str, object] | None = None,
                 counters: dict[str, Callable[[], int | None]] | None = None,
                 *, transfers: bool = True, where: str = "steady-state"):
    """Guard a steady-state region: no recompiles, no implicit D2H.

    ``entrypoints`` maps names to jitted callables (compile counts read via
    ``_cache_size``); ``counters`` maps names to explicit count getters
    (e.g. ``lambda: engine.trace_count``).  Entrypoints must be *warm* —
    call them once before entering the guard.
    """
    watch = _normalize(entrypoints, counters)
    before = _snapshot(watch)
    ctx = (jax.transfer_guard_device_to_host("disallow") if transfers
           else contextlib.nullcontext())
    try:
        with ctx:
            yield
    except Exception as e:  # re-badge jax's transfer error with the rule ID
        if "transfer" in str(e).lower() and "disallow" in str(e).lower():
            _guard_registry().counter(
                "pallint_implicit_transfers_total",
                "GR302 implicit device->host transfers caught by the "
                "trace guard").inc(where=where)
            raise GuardViolation([Finding(
                "GR302", where, 0,
                f"implicit device->host transfer in steady state: {e}")]
            ) from e
        raise
    after = _snapshot(watch)
    reg = _guard_registry()
    compile_gauge = reg.gauge(
        "pallint_compile_count",
        "cached jit specializations per guarded entrypoint")
    for name, count in after.items():
        if count is not None:
            compile_gauge.set(count, entrypoint=name)
    grew = [
        Finding("GR301", where, 0,
                f"{name!r} recompiled in steady state "
                f"({before[name]} -> {after[name]} specializations)")
        for name in watch
        if before[name] is not None and after[name] is not None
        and after[name] > before[name]
    ]
    if grew:
        reg.counter(
            "pallint_recompiles_total",
            "GR301 steady-state recompiles caught by the trace guard"
        ).inc(len(grew), where=where)
        raise GuardViolation(grew)


# ---------------------------------------------------------------------------
# CLI self-check: drive each public jitted entrypoint through warmup and a
# guarded steady-state run on tiny synthetic workloads.
# ---------------------------------------------------------------------------


def _check_broadcast_engine() -> list[Finding]:
    import numpy as np
    from repro import compat
    from repro.core import engine as beng
    from repro.core import rtree
    from repro.data import datasets, spider

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    rects = spider.uniform(2000, seed=101, max_size=0.02)
    queries = datasets.make_queries(rects, 0.2, seed=102)
    tree = rtree.build_str_3level(rects, leaf_capacity=32, fanout=8)
    eng = beng.BroadcastEngine(tree, mesh, batch_size=64)
    eng.query(queries[:64])                        # warmup
    try:
        with steady_state(entrypoints={"broadcast_step": eng._step},
                          counters={"broadcast_trace":
                                    lambda: eng.trace_count},
                          where="BroadcastEngine.query"):
            eng.query(queries)
    except GuardViolation as e:
        return e.findings
    return []


def _check_subtree_engine() -> list[Finding]:
    from repro import compat
    from repro.core import subtree
    from repro.data import datasets, spider

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    rects = spider.gaussian(1500, seed=103, max_size=0.02)
    queries = datasets.make_queries(rects, 0.2, seed=104)
    eng = subtree.SubtreeEngine(rects, mesh, leaf_capacity=64, batch_size=64)
    eng.query(queries[:64])                        # warmup
    try:
        with steady_state(entrypoints={"subtree_step": eng._step},
                          counters={"subtree_trace":
                                    lambda: eng.trace_count},
                          where="SubtreeEngine.query"):
            eng.query(queries)
    except GuardViolation as e:
        return e.findings
    return []


def _check_serve_decode_step() -> list[Finding]:
    import jax.numpy as jnp
    import numpy as np
    from repro import compat, configs
    from repro.models import api
    from repro.serve import serve_loop

    cfg = configs.get_config("llama3.2-1b", smoke=True)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    bs, seq = 2, 16
    step, _, st_shapes, _ = serve_loop.make_decode_step(cfg, mesh, bs, seq,
                                                        dtype=jnp.float32)
    params = api.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    state = api.init_decode_state(cfg, bs, seq, dtype=jnp.float32)
    # Place the cache on its steady-state shardings up front — feeding the
    # uncommitted init state would cost one extra (warmup-only)
    # specialization once the donated output comes back committed.
    state = jax.device_put(state,
                           serve_loop.state_shardings(cfg, mesh, st_shapes))
    rng = np.random.default_rng(105)

    def batch(pos):
        return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (bs, 1)),
                                      jnp.int32),
                "pos": jnp.asarray(pos, jnp.int32)}

    _, state = step(params, state, batch(0))        # warmup
    try:
        with steady_state(entrypoints={"decode_step": step},
                          where="serve_loop.decode_step"):
            for pos in range(1, 4):
                _, state = step(params, state, batch(pos))
    except GuardViolation as e:
        return e.findings
    return []


ENTRYPOINT_CHECKS: dict[str, Callable[[], list[Finding]]] = {
    "broadcast_engine": _check_broadcast_engine,
    "subtree_engine": _check_subtree_engine,
    "serve_decode_step": _check_serve_decode_step,
}


def run_entrypoint_checks(names=None) -> list[Finding]:
    """Run the guard self-check over the public jitted entrypoints."""
    findings: list[Finding] = []
    for name, check in ENTRYPOINT_CHECKS.items():
        if names is not None and name not in names:
            continue
        findings.extend(check())
    return findings
