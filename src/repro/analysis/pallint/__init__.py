"""pallint — device-residency lint + compile/transfer guard subsystem.

Enforces the hot-path doctrine (DESIGN.md Sec 10): static AST rules
(PL1xx), Pallas contract checks (PC2xx), and runtime trace guards (GR3xx).

    python -m repro.analysis.pallint src tests benchmarks
"""
from repro.analysis.pallint.core import (  # noqa: F401
    Finding, Rule, lint_file, lint_paths, registry)
from repro.analysis.pallint.guards import (  # noqa: F401
    GuardViolation, compile_count, steady_state)
