"""pytest plugin: the shared steady-state trace-guard fixture.

Registered from the repo-root ``conftest.py`` so every tier-1 test can
assert the hot-path doctrine at runtime without rolling its own
trace-count bookkeeping:

    def test_engine_steady_state(pallint_steady_state):
        eng = BroadcastEngine(...)
        eng.query(warmup)                    # compile once
        with pallint_steady_state(entrypoints={"step": eng._step},
                                  counters={"trace": lambda: eng.trace_count}):
            eng.query(queries)               # must not retrace or sync

Inside the ``with`` block, any implicit device→host transfer and any growth
of a watched compile counter raises :class:`GuardViolation` (GR301/GR302),
failing the test with the rule ID and the offending entrypoint name.
"""
from __future__ import annotations

import pytest

from repro.analysis.pallint import guards


@pytest.fixture
def pallint_steady_state():
    """Factory fixture: the :func:`guards.steady_state` context manager."""
    return guards.steady_state


@pytest.fixture
def pallint_compile_count():
    """Read a jitted callable's compile-cache size (None if unsupported)."""
    return guards.compile_count
