# Static + runtime analysis tooling for the repro codebase (pallint).
