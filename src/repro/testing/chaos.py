"""Deterministic fault-injection harness for the spatial serving stack.

Real PIM systems exhibit wide per-DPU latency variance (PrIM, PAPERS.md) and
production fleets lose devices, hit allocator limits, and occasionally return
garbage.  This module makes those failures *reproducible*: faults are
scheduled by call index against the two seams the serving loop exposes —

* the jitted query step (``SpatialServer._step`` — the same callable
  ``stream_batches``/``make_query_step`` produce), and
* batch staging (``SpatialServer._place`` — ``jax.device_put``).

Fault kinds (the chaos suite drives each through the server):

==============  ===========================================================
``device_loss``  the step raises :class:`DeviceLostError` (models an XLA
                 "device lost / INTERNAL" runtime failure)
``straggler``    the step sleeps ``delay_s`` before computing (models a
                 slow shard; trips the server watchdog when over budget)
``nan_counts``   the step returns a float batch with NaNs (models corrupted
                 DMA / kernel output; trips the dtype sanity check)
``corrupt``      the step returns out-of-range int counts (trips the bounds
                 sanity check or the sampled oracle cross-check)
``oom``          staging raises :class:`PlacementOOMError` (models a
                 RESOURCE_EXHAUSTED on ``device_put``)
==============  ===========================================================

A plan is a list of :class:`Fault` entries, each naming a kind, the 0-based
call index at which it fires, and how many consecutive calls it affects —
no randomness, so every chaos test replays exactly.  ``install`` wraps a
:class:`~repro.serve.spatial_serve.SpatialServer` in place; ``wrap_step`` /
``wrap_place`` wrap bare callables for use at the ``stream_batches`` seam.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import numpy as np

DEVICE_LOSS = "device_loss"
STRAGGLER = "straggler"
NAN_COUNTS = "nan_counts"
CORRUPT = "corrupt"
OOM = "oom"

_STEP_KINDS = (DEVICE_LOSS, STRAGGLER, NAN_COUNTS, CORRUPT)
_PLACE_KINDS = (OOM,)

KINDS = _STEP_KINDS + _PLACE_KINDS


class DeviceLostError(RuntimeError):
    """Injected stand-in for an XLA device-loss runtime error."""


class PlacementOOMError(RuntimeError):
    """Injected stand-in for RESOURCE_EXHAUSTED during ``device_put``."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault: ``kind`` fires on calls
    ``[at_call, at_call + count)`` of its seam."""

    kind: str
    at_call: int
    count: int = 1
    delay_s: float = 0.0      # straggler sleep

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")
        if self.at_call < 0 or self.count < 1:
            raise ValueError("at_call must be >= 0 and count >= 1")

    def active(self, call_idx: int) -> bool:
        return self.at_call <= call_idx < self.at_call + self.count


class ChaosInjector:
    """Deterministic per-call fault injection over the serving seams.

    ``step_calls`` / ``place_calls`` count invocations since installation;
    ``log`` records every injected fault as ``(seam_call_idx, kind)`` so
    tests can assert exactly what fired."""

    def __init__(self, faults: Sequence[Fault],
                 *, sleep: Callable[[float], None] = time.sleep):
        self.faults = list(faults)
        self._sleep = sleep
        self.step_calls = 0
        self.place_calls = 0
        self.log: list[tuple[int, str]] = []

    def _match(self, idx: int, kinds: tuple[str, ...]) -> Fault | None:
        for f in self.faults:
            if f.kind in kinds and f.active(idx):
                return f
        return None

    def wrap_step(self, step: Callable) -> Callable:
        """Wrap a jitted query step (the ``make_query_step`` seam)."""

        def chaos_step(*args, **kwargs):
            idx = self.step_calls
            self.step_calls += 1
            fault = self._match(idx, _STEP_KINDS)
            if fault is None:
                return step(*args, **kwargs)
            self.log.append((idx, fault.kind))
            if fault.kind == DEVICE_LOSS:
                raise DeviceLostError(
                    f"injected device loss at step call {idx}")
            if fault.kind == STRAGGLER:
                self._sleep(fault.delay_s)
                return step(*args, **kwargs)
            out = np.asarray(step(*args, **kwargs))
            if fault.kind == NAN_COUNTS:
                bad = out.astype(np.float64)
                bad[:: max(1, len(bad) // 4)] = np.nan
                return bad
            # CORRUPT: exact-shape int garbage, out of [0, num_rects]
            bad = out.copy()
            bad[:: max(1, len(bad) // 4)] = -7
            return bad

        return chaos_step

    def wrap_place(self, place: Callable) -> Callable:
        """Wrap batch staging (the ``jax.device_put`` seam)."""

        def chaos_place(*args, **kwargs):
            idx = self.place_calls
            self.place_calls += 1
            fault = self._match(idx, _PLACE_KINDS)
            if fault is not None:
                self.log.append((idx, fault.kind))
                raise PlacementOOMError(
                    f"injected RESOURCE_EXHAUSTED at placement call {idx}")
            return place(*args, **kwargs)

        return chaos_place

    def install(self, server) -> "ChaosInjector":
        """Wrap a ``SpatialServer``'s fast-path seams in place."""
        server._step = self.wrap_step(server._step)
        server._place = self.wrap_place(server._place)
        return self
