"""Deterministic fault-injection harness for the spatial serving stack.

Real PIM systems exhibit wide per-DPU latency variance (PrIM, PAPERS.md) and
production fleets lose devices, hit allocator limits, and occasionally return
garbage.  This module makes those failures *reproducible*: faults are
scheduled by call index against the two seams the serving loop exposes —

* the jitted query step (``SpatialServer._step`` — the same callable
  ``stream_batches``/``make_query_step`` produce), and
* batch staging (``SpatialServer._place`` — ``jax.device_put``).

Fault kinds (the chaos suite drives each through the server):

==============  ===========================================================
``device_loss``  the step raises :class:`DeviceLostError` (models an XLA
                 "device lost / INTERNAL" runtime failure)
``straggler``    the step sleeps ``delay_s`` before computing (models a
                 slow shard; trips the server watchdog when over budget)
``nan_counts``   the step returns a float batch with NaNs (models corrupted
                 DMA / kernel output; trips the dtype sanity check)
``corrupt``      the step returns out-of-range int counts (trips the bounds
                 sanity check or the sampled oracle cross-check)
``oom``          staging raises :class:`PlacementOOMError` (models a
                 RESOURCE_EXHAUSTED on ``device_put``)
==============  ===========================================================

Replica-level kinds (router tier — see :class:`ReplicaChaos`, which wraps a
``repro.serve.router.Replica`` instead of a server seam):

=================  ========================================================
``replica_crash``  ``submit`` raises :class:`ReplicaCrashError` (models a
                   whole replica process dying; the router must fail over)
``replica_hang``   ``submit`` returns a ticket that never completes (models
                   a wedged replica; the router's attempt timeout / hedge
                   must cover it)
``poison``         the replica's step returns *in-bounds but wrong* counts —
                   off-by-one, so it slips past the server's cheap bounds
                   sanity check and only the sampled oracle cross-check can
                   catch it (silent-corruption drill for the router)
=================  ========================================================

A plan is a list of :class:`Fault` entries, each naming a kind, the 0-based
call index at which it fires, and how many consecutive calls it affects.
``period`` turns a fault into a repeating (flapping) schedule: from
``at_call`` on, ``count`` calls out of every ``period`` fire.  Either way a
plan is fully deterministic by call index, so every chaos test replays
exactly.  :func:`random_plan` derives a plan from an explicit integer seed
(``numpy.random.default_rng``) — randomized chaos sweeps stay replayable by
logging the seed, and :meth:`ChaosInjector.describe` renders seed + plan +
fired-fault log for failure output.

``install`` wraps a :class:`~repro.serve.spatial_serve.SpatialServer` in
place; ``wrap_step`` / ``wrap_place`` wrap bare callables for use at the
``stream_batches`` seam.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import numpy as np

DEVICE_LOSS = "device_loss"
STRAGGLER = "straggler"
NAN_COUNTS = "nan_counts"
CORRUPT = "corrupt"
OOM = "oom"

REPLICA_CRASH = "replica_crash"
REPLICA_HANG = "replica_hang"
POISON = "poison"

_STEP_KINDS = (DEVICE_LOSS, STRAGGLER, NAN_COUNTS, CORRUPT)
_PLACE_KINDS = (OOM,)
_REPLICA_KINDS = (REPLICA_CRASH, REPLICA_HANG, POISON)

KINDS = _STEP_KINDS + _PLACE_KINDS + _REPLICA_KINDS


class DeviceLostError(RuntimeError):
    """Injected stand-in for an XLA device-loss runtime error."""


class PlacementOOMError(RuntimeError):
    """Injected stand-in for RESOURCE_EXHAUSTED during ``device_put``."""


class ReplicaCrashError(RuntimeError):
    """Injected stand-in for a whole replica dying mid-request."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault.

    With ``period == 0`` (default), ``kind`` fires on calls
    ``[at_call, at_call + count)`` of its seam — a one-shot window.  With
    ``period >= count``, the window repeats: from ``at_call`` on, the first
    ``count`` calls of every ``period``-call cycle fire (a *flapping*
    schedule, e.g. ``period=4, count=2`` = down half the time)."""

    kind: str
    at_call: int
    count: int = 1
    delay_s: float = 0.0      # straggler sleep
    period: int = 0           # 0 = one-shot; >= count = repeat every period

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")
        if self.at_call < 0 or self.count < 1:
            raise ValueError("at_call must be >= 0 and count >= 1")
        if self.period and self.period < self.count:
            raise ValueError("period must be 0 (one-shot) or >= count")

    def active(self, call_idx: int) -> bool:
        if call_idx < self.at_call:
            return False
        if self.period:
            return (call_idx - self.at_call) % self.period < self.count
        return call_idx < self.at_call + self.count


def random_plan(
    seed: int,
    *,
    n_faults: int = 3,
    kinds: Sequence[str] = _STEP_KINDS + _PLACE_KINDS,
    max_call: int = 16,
    max_count: int = 2,
    max_delay_s: float = 0.2,
) -> list[Fault]:
    """Derive a deterministic fault plan from an explicit integer seed.

    Same seed → identical plan, always — the seed is the only state, so a
    failing randomized chaos test replays from the number in its report
    (``ChaosInjector(random_plan(seed), seed=seed)``)."""
    rng = np.random.default_rng(seed)
    kinds = tuple(kinds)
    plan = []
    for _ in range(n_faults):
        kind = kinds[int(rng.integers(len(kinds)))]
        plan.append(Fault(
            kind=kind,
            at_call=int(rng.integers(max_call)),
            count=int(rng.integers(1, max_count + 1)),
            delay_s=(float(rng.uniform(0.01, max_delay_s))
                     if kind in (STRAGGLER, REPLICA_HANG) else 0.0),
        ))
    return plan


class ChaosInjector:
    """Deterministic per-call fault injection over the serving seams.

    ``step_calls`` / ``place_calls`` count invocations since installation;
    ``log`` records every injected fault as ``(seam_call_idx, kind)`` so
    tests can assert exactly what fired.  ``seed`` is carried for
    replayability reporting only (:meth:`describe`) — pass the seed that
    produced the plan via :func:`random_plan`, or None for hand-written
    plans."""

    def __init__(self, faults: Sequence[Fault],
                 *, seed: int | None = None,
                 sleep: Callable[[float], None] = time.sleep):
        self.faults = list(faults)
        self.seed = seed
        self._sleep = sleep
        self.step_calls = 0
        self.place_calls = 0
        self.log: list[tuple[int, str]] = []

    def describe(self) -> str:
        """Replayability string for failure output: seed, plan, fired log."""
        plan = ", ".join(
            f"{f.kind}@{f.at_call}x{f.count}"
            + (f"/{f.period}" if f.period else "")
            + (f"+{f.delay_s:g}s" if f.delay_s else "")
            for f in self.faults) or "(empty)"
        return (f"chaos(seed={self.seed}, plan=[{plan}], "
                f"fired={self.log})")

    __repr__ = describe

    def _match(self, idx: int, kinds: tuple[str, ...]) -> Fault | None:
        for f in self.faults:
            if f.kind in kinds and f.active(idx):
                return f
        return None

    def wrap_step(self, step: Callable) -> Callable:
        """Wrap a jitted query step (the ``make_query_step`` seam)."""

        def chaos_step(*args, **kwargs):
            idx = self.step_calls
            self.step_calls += 1
            fault = self._match(idx, _STEP_KINDS)
            if fault is None:
                return step(*args, **kwargs)
            self.log.append((idx, fault.kind))
            if fault.kind == DEVICE_LOSS:
                raise DeviceLostError(
                    f"injected device loss at step call {idx}")
            if fault.kind == STRAGGLER:
                self._sleep(fault.delay_s)
                return step(*args, **kwargs)
            out = np.asarray(step(*args, **kwargs))
            if fault.kind == NAN_COUNTS:
                bad = out.astype(np.float64)
                bad[:: max(1, len(bad) // 4)] = np.nan
                return bad
            # CORRUPT: exact-shape int garbage, out of [0, num_rects]
            bad = out.copy()
            bad[:: max(1, len(bad) // 4)] = -7
            return bad

        return chaos_step

    def wrap_place(self, place: Callable) -> Callable:
        """Wrap batch staging (the ``jax.device_put`` seam)."""

        def chaos_place(*args, **kwargs):
            idx = self.place_calls
            self.place_calls += 1
            fault = self._match(idx, _PLACE_KINDS)
            if fault is not None:
                self.log.append((idx, fault.kind))
                raise PlacementOOMError(
                    f"injected RESOURCE_EXHAUSTED at placement call {idx}")
            return place(*args, **kwargs)

        return chaos_place

    def install(self, server) -> "ChaosInjector":
        """Wrap a ``SpatialServer``'s fast-path seams in place."""
        server._step = self.wrap_step(server._step)
        server._place = self.wrap_place(server._place)
        return self


class _HungTicket:
    """Stand-in for a request a wedged replica accepted but will never
    answer: ``wait`` blocks until its timeout and reports False, ``done``
    stays False forever."""

    status = "pending"
    reason = "replica_hang"
    count = None
    ids = None
    distances = None
    overflow = None
    aggregates = None
    path = None

    def __init__(self, rect, kind="count"):
        self.rect = rect
        self.kind = kind

    @property
    def done(self) -> bool:
        return False

    def wait(self, timeout: float | None = None) -> bool:
        if timeout:
            time.sleep(timeout)
        return False


class ReplicaChaos:
    """Deterministic replica-level fault injection for the router tier.

    Wraps one ``repro.serve.router.Replica`` in place: ``submit`` is the
    seam for ``replica_crash`` (raises) and ``replica_hang`` (returns a
    never-completing ticket); the replica's *server step* is the seam for
    ``poison`` (in-bounds wrong counts — ``count > 0`` answers come back
    off-by-one, which passes the server's bounds sanity check and is only
    caught by a sampled oracle cross-check).  Call indices count ``submit``
    invocations for crash/hang and step invocations for poison, so the two
    schedules compose independently."""

    def __init__(self, faults: Sequence[Fault],
                 *, seed: int | None = None):
        self.faults = list(faults)
        self.seed = seed
        self.submit_calls = 0
        self.step_calls = 0
        self.log: list[tuple[int, str]] = []

    def describe(self) -> str:
        plan = ", ".join(
            f"{f.kind}@{f.at_call}x{f.count}"
            + (f"/{f.period}" if f.period else "")
            for f in self.faults) or "(empty)"
        return (f"replica_chaos(seed={self.seed}, plan=[{plan}], "
                f"fired={self.log})")

    __repr__ = describe

    def _match(self, idx: int, kinds: tuple[str, ...]) -> Fault | None:
        for f in self.faults:
            if f.kind in kinds and f.active(idx):
                return f
        return None

    def install(self, replica) -> "ReplicaChaos":
        """Wrap a router ``Replica``'s submit + server-step seams in place."""
        inner_submit = replica.submit

        def chaos_submit(rect, **kwargs):
            idx = self.submit_calls
            self.submit_calls += 1
            fault = self._match(idx, (REPLICA_CRASH, REPLICA_HANG))
            if fault is None:
                return inner_submit(rect, **kwargs)
            self.log.append((idx, fault.kind))
            if fault.kind == REPLICA_CRASH:
                raise ReplicaCrashError(
                    f"injected replica crash at submit call {idx} "
                    f"on {replica.name!r}")
            return _HungTicket(rect, kwargs.get("kind", "count"))

        replica.submit = chaos_submit

        inner_step = replica.server._step

        def chaos_step(*args, **kwargs):
            idx = self.step_calls
            self.step_calls += 1
            out = inner_step(*args, **kwargs)
            fault = self._match(idx, (POISON,))
            if fault is None:
                return out
            self.log.append((idx, fault.kind))
            out = np.asarray(out)
            # In-bounds off-by-one: wrong, but passes the [0, num_rects]
            # bounds sanity check — only an oracle cross-check catches it.
            return np.where(out > 0, out - 1, out + 1).astype(out.dtype)

        replica.server._step = chaos_step
        return self
