"""Broadcast PIM R-tree engine on a TPU mesh (paper Section III-C).

The paper's CPU→DPU pipeline, re-expressed in JAX SPMD:

==========================  =================================================
paper (UPMEM)               this engine (TPU mesh)
==========================  =================================================
host builds STR tree        :func:`repro.core.rtree.build_str_3level` (numpy)
BFS serialization           structure-of-arrays, leaf level contiguous
broadcast upper headers     replicated operand — ``PartitionSpec()``
scatter leaf slices         leaf arrays sharded over *all* mesh axes, axis 1
                            of the (4, N) coordinate layout; contiguous BFS
                            slices == the paper's partitions
broadcast query batch       replicated operand, fixed batch size (≤10k)
DPU two-phase kernel        shard_map body: fused Phase-1 cover filter +
                            Phase-2 Pallas tile-scan kernel (DESIGN.md Sec 4)
host aggregates counts      ``jax.lax.psum`` over the mesh (on-fabric; a
                            beyond-paper improvement — DESIGN.md Sec 7)
==========================  =================================================

Placement-time metadata cache (DESIGN.md Sec 3): everything the steady-state
batch loop needs besides the queries themselves — transposed leaf
coordinates, per-device leaf-tile MBRs, covering level-1 MBRs, and the
sparse-path tile occupancy table — is computed once in :func:`shard_tree` and
device-placed in ``BroadcastEngine.__init__``.  The jitted query step
performs zero per-batch host-side metadata construction; per-batch query-tile
MBRs are derived on device inside the step.

Per-device Phase-1 neighborhoods: device ``d`` holds the contiguous leaf
slice ``[d·Lp, (d+1)·Lp)``; its covering level-1 nodes are those whose child
ranges intersect the slice — the paper's "candidate level-1 nodes are
determined by the DPU index", giving O(1) upper-level filtering per query.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
import zlib
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core.types import EMPTY_RECT, SerializedRTree, mbr_of
from repro.kernels import ops
from repro.obs import phases as obs_phases
from repro.obs import trace as obs_trace

DEFAULT_BATCH = 10_000  # paper: "queries are processed in batches of up to 10,000"

_INT32_MIN = -(2**31)
_INT32_MAX = 2**31 - 1


class QueryValidationError(ValueError):
    """A malformed query batch was rejected at the engine boundary."""


def validate_queries(
    queries, *, strict: bool = False, where: str = "queries",
    points: bool = False,
) -> np.ndarray:
    """Validate and canonicalize a query batch at the engine boundary.

    The kernels assume well-formed int32 corner rects ``[xmin, ymin, xmax,
    ymax]`` with ``lo <= hi`` — anything else silently produces wrong counts
    (a NaN compares false everywhere, an int64 coordinate wraps on the cast,
    a ``lo > hi`` rect aliases the EMPTY padding sentinel and counts zero).
    This boundary turns each of those into an explicit contract:

    * shape must be ``(Q, 4)`` — anything else raises;
    * dtype must be integer, or float with finite integral values — NaN/inf
      and fractional coordinates raise;
    * coordinates must fit in int32 — out-of-range values raise rather than
      wrap;
    * ``lo > hi`` rects are canonicalized by swapping the corners (or raise
      when ``strict=True`` — the serving admission path uses strict mode so
      a malformed request is refused, not reinterpreted).

    With ``points=True`` the batch is a ``(Q, 2)`` array of ``[x, y]`` point
    queries (kNN / radius kinds) and is validated as such — an explicit mode
    rather than aliasing ``(x, y, x, y)`` degenerate rects through the rect
    path, so shape errors and the lo>hi rules can't misfire on points.

    Returns a fresh ``(Q, 4)`` (or ``(Q, 2)``) int32 array safe for the
    device pipeline.
    """
    width = 2 if points else 4
    kind = "points" if points else "rects"
    arr = np.asarray(queries)
    if arr.ndim != 2 or arr.shape[-1] != width:
        raise QueryValidationError(
            f"{where}: expected {kind} of shape (Q, {width}), "
            f"got {arr.shape}")
    if arr.dtype.kind == "f":
        if arr.size and not np.isfinite(arr).all():
            raise QueryValidationError(
                f"{where}: NaN/inf coordinates are not valid rects")
        if arr.size and not (np.mod(arr, 1) == 0).all():
            raise QueryValidationError(
                f"{where}: fractional float coordinates — scale to the "
                "fixed-precision int32 grid first (spider.SCALE)")
    elif arr.dtype.kind not in "iu":
        raise QueryValidationError(
            f"{where}: dtype {arr.dtype} is not a coordinate dtype "
            "(expected integer, or float with integral values)")
    if arr.size and (arr.min() < _INT32_MIN or arr.max() > _INT32_MAX):
        raise QueryValidationError(
            f"{where}: coordinates outside the int32 range would wrap "
            "on the device cast")
    out = arr.astype(np.int32, copy=True)
    if points:
        return np.ascontiguousarray(out, dtype=np.int32)
    if out.size:
        flipped = (out[:, 0] > out[:, 2]) | (out[:, 1] > out[:, 3])
        if flipped.any():
            if strict:
                raise QueryValidationError(
                    f"{where}: {int(flipped.sum())} rect(s) with lo > hi "
                    "(strict mode rejects rather than canonicalizes)")
            lo = np.minimum(out[:, :2], out[:, 2:])
            hi = np.maximum(out[:, :2], out[:, 2:])
            out = np.concatenate([lo, hi], axis=1)
    return np.ascontiguousarray(out, dtype=np.int32)


def validate_radii(radii, *, num_points: int | None = None,
                   where: str = "radii") -> np.ndarray:
    """Validate a per-query radius vector for the radius query kind.

    NaN/inf, fractional, negative, or out-of-int32-range radii raise (a NaN
    radius compares false against every distance and silently returns empty
    results — the exact failure mode the boundary exists to catch).  Returns
    a fresh ``(Q,) int32`` array.
    """
    arr = np.asarray(radii)
    if arr.ndim != 1:
        raise QueryValidationError(
            f"{where}: expected shape (Q,), got {arr.shape}")
    if num_points is not None and arr.shape[0] != num_points:
        raise QueryValidationError(
            f"{where}: {arr.shape[0]} radii for {num_points} points")
    if arr.dtype.kind == "f":
        if arr.size and not np.isfinite(arr).all():
            raise QueryValidationError(
                f"{where}: NaN/inf radii are not valid")
        if arr.size and not (np.mod(arr, 1) == 0).all():
            raise QueryValidationError(
                f"{where}: fractional radii — scale to the fixed-precision "
                "int32 grid first (spider.SCALE)")
    elif arr.dtype.kind not in "iu":
        raise QueryValidationError(
            f"{where}: dtype {arr.dtype} is not a radius dtype")
    if arr.size and (arr.min() < 0 or arr.max() > _INT32_MAX):
        raise QueryValidationError(
            f"{where}: radii must be in [0, int32 max]")
    return np.ascontiguousarray(arr.astype(np.int32, copy=True))


def validate_k(k, *, where: str = "k") -> int:
    """Validate a kNN ``k``: a positive Python int (k <= 0 rejected)."""
    try:
        kv = int(k)
    except (TypeError, ValueError):
        raise QueryValidationError(f"{where}: k must be an integer, got {k!r}")
    if isinstance(k, float) and k != kv:
        raise QueryValidationError(f"{where}: k must be integral, got {k!r}")
    if kv <= 0:
        raise QueryValidationError(f"{where}: k must be >= 1, got {kv}")
    return kv


def _mesh_device_count(mesh: jax.sharding.Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names]))


@dataclasses.dataclass(frozen=True)
class ShardedLayout:
    """Host-computed device layout plus the placement-time metadata cache.

    ``leaf_rects_flat`` keeps the (N, 4) row layout for inspection and the
    communication model; the engine device-places its transpose.  With
    ``tile`` set, each device's slice is EMPTY-padded to a tile multiple and
    the per-tile MBRs / occupancy are precomputed here, once, instead of
    inside every jitted batch step.
    """

    leaf_rects_flat: np.ndarray   # (D * R_loc, 4) int32, EMPTY-padded
    cover_mbrs: np.ndarray        # (D, Kmax, 4) int32, EMPTY-padded
    num_devices: int
    rects_per_device: int
    kmax: int
    leaves_per_device: int
    tile: int | None = None
    rect_tile_mbrs: np.ndarray | None = None   # (D, NT, 4) int32
    tile_occupancy: np.ndarray | None = None   # (D, NT) int32 valid rects
    # Source IDs aligned with leaf_rects_flat rows (-1 for padding).  Built
    # from tree.leaf_ids; hand-built trees without IDs get BFS-packed
    # positional IDs so the query subsystem is always well-defined.
    leaf_ids_flat: np.ndarray | None = None    # (D * R_loc,) int32

    @property
    def leaf_bytes(self) -> int:
        return self.leaf_rects_flat.nbytes

    @property
    def header_bytes(self) -> int:
        return self.cover_mbrs.nbytes // self.num_devices  # broadcast once

    def fingerprint(self) -> str:
        """Content hash of the placed layout — the layout-version handle.

        Two layouts built from the same rects with the same sharding hash
        identically; any rebuild (new STR pack, different device count)
        changes it.  The serving router uses this as its version fence
        token: a batch is guaranteed to never mix layouts because replicas
        only pair (route, hedge) within one fingerprint."""
        h = zlib.crc32(self.leaf_rects_flat.tobytes())
        h = zlib.crc32(np.ascontiguousarray(self.cover_mbrs).tobytes(), h)
        return f"{self.num_devices}d-{h:08x}"

    @property
    def metadata_bytes(self) -> int:
        """One-time scatter volume of the cached tile metadata.

        Counts only what is actually device-placed (the tile MBRs);
        ``tile_occupancy`` stays host-side as layout introspection and
        prefetch-table statistics, so it is not charged here."""
        if self.rect_tile_mbrs is None:
            return 0
        return self.rect_tile_mbrs.nbytes


def shard_tree(
    tree: SerializedRTree, num_devices: int, *, tile: int | None = None
) -> ShardedLayout:
    """Partition the BFS leaf level into contiguous per-device slices and
    compute each device's covering level-1 MBR neighborhood.

    With ``tile`` (the kernel's rect-tile size TR), the per-device slices are
    padded to a tile multiple and the leaf-tile MBR / occupancy tables are
    precomputed — the placement-time half of the device-resident pipeline.
    """
    with obs_trace.span("shard_tree", phase=obs_phases.BUILD,
                        devices=int(num_devices), tile=tile):
        return _shard_tree_inner(tree, num_devices, tile)


def _shard_tree_inner(tree, num_devices, tile):
    d = int(num_devices)
    leaf_rects = np.asarray(tree.leaf_rects)           # (L, B, 4)
    l, b, _ = leaf_rects.shape
    if getattr(tree, "leaf_ids", None) is not None:
        leaf_ids = np.asarray(tree.leaf_ids, dtype=np.int32)  # (L, B)
    else:
        # Hand-built tree without source IDs: BFS-packed positional IDs
        # over the valid slots (padding slots get -1).
        valid = leaf_rects[..., 0] <= leaf_rects[..., 2]
        leaf_ids = np.where(
            valid, np.cumsum(valid).reshape(l, b) - 1, -1
        ).astype(np.int32)
    lp = math.ceil(l / d)
    pad = d * lp - l
    if pad:
        leaf_rects = np.concatenate(
            [leaf_rects, np.tile(EMPTY_RECT, (pad, b, 1))], axis=0
        )
        leaf_ids = np.concatenate(
            [leaf_ids, np.full((pad, b), -1, dtype=np.int32)], axis=0
        )
    per_dev = leaf_rects.reshape(d, lp * b, 4)
    per_dev_ids = leaf_ids.reshape(d, lp * b)
    rect_tile_mbrs = tile_occupancy = None
    if tile is not None:
        rp = math.ceil(lp * b / tile) * tile
        if rp != lp * b:
            per_dev = np.concatenate(
                [per_dev, np.tile(EMPTY_RECT, (d, rp - lp * b, 1))], axis=1
            )
            per_dev_ids = np.concatenate(
                [per_dev_ids,
                 np.full((d, rp - lp * b), -1, dtype=np.int32)], axis=1
            )
        tiles = per_dev.reshape(d, rp // tile, tile, 4)
        rect_tile_mbrs = mbr_of(tiles)
        valid = tiles[..., 0] <= tiles[..., 2]
        tile_occupancy = valid.sum(axis=2).astype(np.int32)
    flat = per_dev.reshape(-1, 4)
    assert tree.l1_child_start.dtype == np.int32, tree.l1_child_start.dtype
    assert tree.l1_child_count.dtype == np.int32, tree.l1_child_count.dtype

    # 32-bit index-dtype doctrine (pallint PL109): child ranges are leaf
    # indices and stay int32 end to end.
    starts = np.asarray(tree.l1_child_start, dtype=np.int32)
    counts = np.asarray(tree.l1_child_count, dtype=np.int32)
    ends = starts + counts
    l1_mbrs = np.asarray(tree.l1_mbrs)
    # level-1 nodes whose child leaf range intersects each device slice
    dev_lo = np.arange(d, dtype=np.int32)[:, None] * lp
    dev_hi = np.minimum(dev_lo + lp, l)
    hits = (starts[None, :] < dev_hi) & (ends[None, :] > dev_lo)   # (D, C1)
    kmax = max(1, int(hits.sum(axis=1).max()))
    cover_mbrs = np.tile(EMPTY_RECT, (d, kmax, 1))
    for dev in range(d):
        c = l1_mbrs[hits[dev]]
        cover_mbrs[dev, : c.shape[0]] = c
    return ShardedLayout(
        leaf_rects_flat=flat.astype(np.int32),
        cover_mbrs=cover_mbrs.astype(np.int32),
        num_devices=d,
        rects_per_device=flat.shape[0] // d,
        kmax=kmax,
        leaves_per_device=lp,
        tile=tile,
        rect_tile_mbrs=rect_tile_mbrs,
        tile_occupancy=tile_occupancy,
        leaf_ids_flat=per_dev_ids.reshape(-1).astype(np.int32),
    )


def make_query_step(
    mesh: jax.sharding.Mesh,
    *,
    impl: str = ops.DEFAULT_IMPL,
    tq: int = 512,
    tr: int = 1024,
    donate_queries: bool = True,
    on_trace: Callable[[], None] | None = None,
):
    """Build the jitted SPMD query step for ``mesh``.

    Returns ``step(leaf_coords, rect_tile_mbrs, cover_mbrs, queries) ->
    counts`` where the (4, N) leaf coordinates are sharded over all mesh axes
    on axis 1, tile metadata and headers are sharded one-row-per-device, and
    queries/counts are replicated.  All rect-side metadata is placement-time
    input — the step derives only query-tile MBRs per batch, on device.  This
    function is what the multi-pod dry-run lowers and compiles.

    ``on_trace`` fires once per (re)trace — the steady-state zero-host-work
    property is asserted against it in the tests.
    """
    axes = tuple(mesh.axis_names)
    p_coords = jax.sharding.PartitionSpec(None, axes)
    p_meta = jax.sharding.PartitionSpec(axes)
    p_rep = jax.sharding.PartitionSpec()

    def shard_fn(local_coords, local_rmbrs, local_cover, queries):
        if on_trace is not None:
            on_trace()
        cover = local_cover.reshape(-1, 4)              # (Kmax, 4)
        rmbrs = local_rmbrs.reshape(-1, 4)              # (NT, 4)
        # Two-phase filter+scan, Phase-1 fused into the kernel
        # (WRAM-resident metadata in the paper; VMEM/registers here).
        counts = ops.overlap_counts_fused(
            queries, local_coords, rmbrs, cover, impl=impl, tq=tq, tr=tr
        )
        # Host aggregation in the paper; on-fabric psum here.
        return jax.lax.psum(counts, axes)

    fn = compat.shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(p_coords, p_meta, p_meta, p_rep),
        out_specs=p_rep,
        check_vma=False,  # Pallas calls don't carry varying-mesh-axis info
    )
    return jax.jit(fn, donate_argnums=(3,) if donate_queries else ())


def morton_order(rects: np.ndarray, shift: int = 12) -> np.ndarray:
    """Morton (Z-curve) ordering of rect centres — beyond-paper §Perf S2:
    spatially coherent query batches make query-tile MBRs tight, so the
    kernel's tile-MBR pruning (and the scalar-prefetch kernel's DMA skip)
    fires; measured 6.7× fewer active (query-tile × rect-tile) pairs on the
    lakes workload vs arrival order.

    Centres are offset to start at zero, then 21 bits per axis are
    interleaved (42-bit code) — with the default ``shift`` of 12 that spans
    the full int33 coordinate range, so large-coordinate datasets no longer
    collapse into one Z-code bucket (the old code interleaved only 10 bits).
    """
    if rects.shape[0] == 0:
        return np.empty(0, dtype=np.int32)
    # 64-bit intermediate: centre sums overflow int32 on extreme coordinates
    r = rects.astype(np.int64)    # pallint: disable=PL109
    cx = (r[:, 0] + r[:, 2]) // 2
    cy = (r[:, 1] + r[:, 3]) // 2
    cx = ((cx - cx.min()) >> shift).astype(np.uint64)
    cy = ((cy - cy.min()) >> shift).astype(np.uint64)
    code = np.zeros(len(rects), np.uint64)
    for i in range(21):
        code |= ((cx >> np.uint64(i)) & np.uint64(1)) << np.uint64(2 * i)
        code |= ((cy >> np.uint64(i)) & np.uint64(1)) << np.uint64(2 * i + 1)
    # permutation indices follow the 32-bit index doctrine (pallint PL109)
    return np.argsort(code, kind="stable").astype(np.int32)


def stream_batches(
    step: Callable,
    operands: Sequence[Any],
    queries: np.ndarray,
    batch_size: int,
    rep_sharding: jax.sharding.NamedSharding,
    *,
    pad_row: np.ndarray | None = None,
) -> Any:
    """Pipelined fixed-shape batch loop (DESIGN.md Sec 5).

    The next batch is staged (``device_put``) while the current one computes
    — jax dispatch is asynchronous, so the host never blocks between batches;
    query buffers are donated by the step and host references dropped as soon
    as each dispatch is issued.  Results are synced once at the end instead
    of per batch.

    ``step`` may return a single array or any pytree of arrays whose leaves
    all carry the query axis first (the query-kind steps return tuples);
    leaves are concatenated across batches and sliced back to the true query
    count.  ``pad_row`` overrides the EMPTY-rect padding row for payloads
    whose padding sentinel differs (e.g. the radius kind's negative-radius
    rows) — it must be a no-match row for the step's predicate.

    Tracing (DESIGN.md Sec 12): with the tracer enabled each batch records a
    ``stage`` (h2d) and ``dispatch`` (kernel) span and the loop ends with one
    ``sync_retrieve`` (d2h) span.  Because dispatch is asynchronous, the
    dispatch spans measure *host dispatch cost only* — device kernel wait is
    absorbed by the end-of-set sync span by design.  Fig-10-style kernel
    slices come from the blocking harness
    (:func:`repro.obs.phases.measure_query_phases`), not from this loop.
    Disabled tracing costs one attribute check per span site.
    """
    queries = np.asarray(queries, dtype=np.int32)
    q = queries.shape[0]
    if q == 0:
        return np.empty(0, dtype=np.int32)
    bs = int(batch_size)
    nb = math.ceil(q / bs)
    if pad_row is None:
        pad_row = EMPTY_RECT
    pad_row = np.asarray(pad_row, dtype=np.int32).reshape(1, -1)
    with obs_trace.span("stream_batches", phase=obs_phases.HOST,
                        batches=nb, batch_size=bs, queries=q):
        pad = nb * bs - q
        if pad:
            queries = np.concatenate([queries, np.tile(pad_row, (pad, 1))])
        batches = queries.reshape(nb, bs, queries.shape[1])

        outs = []
        with obs_trace.span("stage", phase=obs_phases.H2D, batch=0):
            staged = jax.device_put(batches[0], rep_sharding)
        with warnings.catch_warnings():
            # The step donates its query buffer (a liveness hint); the (Q,)
            # count output can never alias the (Q, 4) input, so XLA's compile
            # advises the donation is unusable for aliasing — expected here,
            # and suppressed only for this loop, not process-wide.
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            for i in range(nb):
                if i + 1 < nb:
                    with obs_trace.span("stage", phase=obs_phases.H2D,
                                        batch=i + 1):
                        nxt = jax.device_put(batches[i + 1], rep_sharding)
                else:
                    nxt = None
                with obs_trace.span("dispatch", phase=obs_phases.KERNEL,
                                    batch=i):
                    outs.append(step(*operands, staged))
                staged = nxt          # drop our reference to the donated buffer
        # The one sanctioned host sync of the hot path: a single end-of-set
        # barrier plus an *explicit* device→host retrieval (jax.device_get),
        # so the whole loop runs clean under the pallint trace guard's
        # transfer_guard_device_to_host("disallow").
        with obs_trace.span("sync_retrieve", phase=obs_phases.D2H,
                            result_bytes=q * 4):
            jax.block_until_ready(outs)    # pallint: disable=PL102
            host = jax.device_get(outs)
            return jax.tree_util.tree_map(
                lambda *xs: np.concatenate(xs)[:q], host[0], *host[1:])


class QueryKindMixin:
    """Result-materializing query surface shared by both engines.

    Adds ``query_ids`` / ``query_knn`` / ``query_radius`` /
    ``query_aggregate`` on top of the count path (DESIGN.md Sec 14).  Host
    classes provide ``mesh``, ``batch_size``, ``_rep_sh``, ``_impl`` /
    ``_tq`` / ``_tr``, ``trace_count``, a ``_kind_operands()`` tuple in the
    uniform ``(coords, ids, tile_mbrs, covers)`` order, and the placed
    host-side arrays (``placed_rects`` / ``placed_ids``) the oracles and
    the serving degradation path consume.

    Kind steps are compiled lazily and cached per ``(kind, parameter)`` —
    a second ``query_knn(..., k=8)`` call reuses the compiled step, and the
    serving layer reaches the same cache through :meth:`kind_step`.
    """

    _kind_steps: dict

    def _kind_operands(self):
        raise NotImplementedError

    @property
    def placed_rects(self) -> np.ndarray:
        raise NotImplementedError

    @property
    def placed_ids(self) -> np.ndarray:
        raise NotImplementedError

    def _bump_trace(self):
        self.trace_count += 1

    def kind_step(self, kind: str, param: int | None):
        """The cached jitted step for ``kind`` (param: kcap or k)."""
        from repro.query import pipelines as qp  # lazy: engine ↔ query cycle
        key = (kind, param)
        step = self._kind_steps.get(key)
        if step is None:
            kw = {}
            if kind in ("ids", "radius"):
                kw["kcap"] = param
            elif kind == "knn":
                kw["k"] = param
            step = qp.make_kind_step(
                self.mesh, kind, impl=self._impl, tq=self._tq, tr=self._tr,
                on_trace=self._bump_trace, **kw)
            self._kind_steps[key] = step
        return step

    def _empty_result(self, kind: str, param: int | None):
        from repro.query import pipelines as qp
        if kind in ("ids", "radius"):
            out = (np.zeros((0, param), np.int32), np.zeros((0,), np.int32))
        elif kind == "knn":
            out = (np.zeros((0, param), np.float32),
                   np.zeros((0, param), np.int32))
        else:
            out = (np.zeros((0,), np.int32), np.zeros((0, 3), np.float32),
                   np.zeros((0, 4), np.int32))
        return qp.assemble(kind, out, kcap=param or 0)

    def _run_kind(self, kind: str, payload: np.ndarray, param: int | None):
        from repro.query import pipelines as qp
        q = int(payload.shape[0])
        name = type(self).__name__
        with obs_trace.span(f"{name}.query_{kind}", phase=obs_phases.HOST,
                            queries=q, query_kind=kind):
            if q == 0:
                return self._empty_result(kind, param)
            inv = None
            if getattr(self, "sort_queries", False):
                order = morton_order(qp.payload_rects(kind, payload))
                inv = np.argsort(order, kind="stable")
                payload = payload[order]
            out = stream_batches(
                self.kind_step(kind, param), self._kind_operands(),
                payload, self.batch_size, self._rep_sh,
                pad_row=qp.PAD_ROWS[kind])
            if inv is not None:
                out = jax.tree_util.tree_map(lambda x: x[inv], out)
            return qp.assemble(kind, out,
                               kcap=param if param is not None else 0)

    # ------------------------------------------------------- public surface

    def query_ids(self, queries: np.ndarray, *, kcap: int = 64):
        """Materialized range query: the source IDs of every rect each query
        rect overlaps, first ``kcap`` per query in placed order, with true
        totals and overflow accounting (:class:`repro.query.SpatialResult`).
        """
        from repro.query import pipelines as qp
        queries = validate_queries(
            queries, where=f"{type(self).__name__}.query_ids")
        kcap = validate_k(kcap, where="query_ids.kcap")
        return self._run_kind("ids", qp.pack_rects(queries), kcap)

    def query_knn(self, points: np.ndarray, k: int = 8):
        """k nearest rects per query point under the shared squared-f32
        metric, ties broken by ascending source ID."""
        from repro.query import pipelines as qp
        points = validate_queries(
            points, points=True, where=f"{type(self).__name__}.query_knn")
        k = validate_k(k, where="query_knn.k")
        return self._run_kind("knn", qp.pack_knn(points), k)

    def query_radius(self, points: np.ndarray, radii: np.ndarray,
                     *, kcap: int = 64):
        """Closed-ball radius query: IDs of rects within ``radii[i]`` of
        ``points[i]`` (squared-f32 metric), capped at ``kcap`` with overflow
        accounting."""
        from repro.query import pipelines as qp
        points = validate_queries(
            points, points=True, where=f"{type(self).__name__}.query_radius")
        radii = validate_radii(
            radii, num_points=points.shape[0],
            where=f"{type(self).__name__}.query_radius")
        kcap = validate_k(kcap, where="query_radius.kcap")
        return self._run_kind("radius", qp.pack_radius(points, radii), kcap)

    def query_aggregate(self, queries: np.ndarray):
        """On-fabric aggregates per query rect: exact count and match bbox,
        float32 centroid/mean-area sums (reduced in-kernel and combined
        across devices without materializing any candidate list)."""
        from repro.query import pipelines as qp
        queries = validate_queries(
            queries, where=f"{type(self).__name__}.query_aggregate")
        return self._run_kind("aggregate", qp.pack_rects(queries), None)


class BroadcastEngine(QueryKindMixin):
    """End-to-end broadcast engine: host build → device placement → batched
    queries.  Mirrors the paper's Fig. 3 workflow.  ``sort_queries`` applies
    Morton ordering once over the whole query set per :meth:`query` call
    (counts are un-permuted on return)."""

    def __init__(
        self,
        tree: SerializedRTree,
        mesh: jax.sharding.Mesh,
        *,
        impl: str = ops.DEFAULT_IMPL,
        tq: int = 512,
        tr: int = 1024,
        batch_size: int = DEFAULT_BATCH,
        sort_queries: bool = False,
    ):
        self.mesh = mesh
        self.batch_size = int(batch_size)
        self.sort_queries = sort_queries
        self.num_devices = _mesh_device_count(mesh)
        self.layout = shard_tree(tree, self.num_devices, tile=tr)
        self.trace_count = 0
        self._impl, self._tq, self._tr = impl, tq, tr
        self._kind_steps = {}

        axes = tuple(mesh.axis_names)
        coords_sh = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(None, axes))
        meta_sh = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(axes))
        rep_sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
        # one-time placement (paper Sec III-C.3): leaf scatter + header
        # broadcast + the tile-metadata cache — nothing below is touched
        # again until the tree changes.
        with obs_trace.span(
                "place", phase=obs_phases.H2D,
                leaf_bytes=int(self.layout.leaf_bytes),
                metadata_bytes=int(self.layout.metadata_bytes),
                header_bytes=int(self.layout.header_bytes)):
            self.leaf_coords = jax.device_put(
                np.ascontiguousarray(self.layout.leaf_rects_flat.T), coords_sh)
            self.rect_tile_mbrs = jax.device_put(
                self.layout.rect_tile_mbrs, meta_sh)
            self.cover_mbrs = jax.device_put(self.layout.cover_mbrs, meta_sh)
            # source IDs ride the same sharding as the leaf slices so the
            # materializing kinds can return them without any host gather
            self.leaf_ids = jax.device_put(self.layout.leaf_ids_flat, meta_sh)
            if obs_trace.enabled():
                # only when tracing: make the placement span measure the
                # actual transfer, not just the async dispatch
                jax.block_until_ready(             # pallint: disable=PL102
                    (self.leaf_coords, self.rect_tile_mbrs, self.cover_mbrs,
                     self.leaf_ids))
        self._rep_sh = rep_sh

        def _count_trace():
            self.trace_count += 1

        self._step = make_query_step(
            mesh, impl=impl, tq=tq, tr=tr, on_trace=_count_trace)

    def query(self, queries: np.ndarray) -> np.ndarray:
        """Batched range-query counts (paper Sec III-C.4/5)."""
        with obs_trace.span("broadcast.query", phase=obs_phases.HOST,
                            queries=int(np.asarray(queries).shape[0])):
            queries = validate_queries(queries, where="BroadcastEngine.query")
            if self.sort_queries:
                order = morton_order(queries)
                inv = np.argsort(order, kind="stable")
                return self._query_inner(queries[order])[inv]
            return self._query_inner(queries)

    def _query_inner(self, queries: np.ndarray) -> np.ndarray:
        return stream_batches(
            self._step,
            (self.leaf_coords, self.rect_tile_mbrs, self.cover_mbrs),
            queries, self.batch_size, self._rep_sh,
        )

    # ---- query-kind surface (QueryKindMixin) -----------------------------
    def _kind_operands(self):
        return (self.leaf_coords, self.leaf_ids, self.rect_tile_mbrs,
                self.cover_mbrs)

    @property
    def placed_rects(self) -> np.ndarray:
        """(N, 4) host copy of the placed leaf rects in device order."""
        return self.layout.leaf_rects_flat

    @property
    def placed_ids(self) -> np.ndarray:
        """(N,) source IDs aligned with :attr:`placed_rects` (-1 padding)."""
        return self.layout.leaf_ids_flat

    # ---- communication-volume model (paper Figs. 7/10, Table III) --------
    def transfer_stats(self, num_queries: int) -> dict[str, int]:
        """Bytes moved host→device / device→host under the paper's model.

        broadcast: headers + tile metadata once; leaves scatter once; queries
        broadcast per batch; results one count per query (fabric-reduced)."""
        nb = math.ceil(num_queries / self.batch_size)
        return {
            "header_broadcast_bytes": self.layout.header_bytes,
            "leaf_scatter_bytes": self.layout.leaf_bytes,
            "metadata_scatter_bytes": self.layout.metadata_bytes,
            "query_broadcast_bytes": nb * self.batch_size * 16,
            "result_bytes": num_queries * 4,
            "per_batch_bytes": self.batch_size * 16,
        }
