"""Broadcast PIM R-tree engine on a TPU mesh (paper Section III-C).

The paper's CPU→DPU pipeline, re-expressed in JAX SPMD:

==========================  =================================================
paper (UPMEM)               this engine (TPU mesh)
==========================  =================================================
host builds STR tree        :func:`repro.core.rtree.build_str_3level` (numpy)
BFS serialization           structure-of-arrays, leaf level contiguous
broadcast upper headers     replicated operand — ``PartitionSpec()``
scatter leaf slices         leaf arrays sharded over *all* mesh axes, axis 0;
                            contiguous BFS slices == the paper's partitions
broadcast query batch       replicated operand, fixed batch size (≤10k)
DPU two-phase kernel        shard_map body: Phase-1 mask from the covering
                            level-1 MBRs, Phase-2 Pallas tile-scan kernel
host aggregates counts      ``jax.lax.psum`` over the mesh (on-fabric; a
                            beyond-paper improvement — flagged in DESIGN.md)
==========================  =================================================

Per-device Phase-1 neighborhoods: device ``d`` holds the contiguous leaf
slice ``[d·Lp, (d+1)·Lp)``; its covering level-1 nodes are those whose child
ranges intersect the slice — the paper's "candidate level-1 nodes are
determined by the DPU index", giving O(1) upper-level filtering per query.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import EMPTY_RECT, SerializedRTree
from repro.kernels import ops
from repro.kernels import ref as kref

DEFAULT_BATCH = 10_000  # paper: "queries are processed in batches of up to 10,000"


def _mesh_device_count(mesh: jax.sharding.Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names]))


@dataclasses.dataclass(frozen=True)
class ShardedLayout:
    """Host-computed device layout: leaf slices and covering L1 headers."""

    leaf_rects_flat: np.ndarray   # (D * R_loc, 4) int32, EMPTY-padded
    cover_mbrs: np.ndarray        # (D, Kmax, 4) int32, EMPTY-padded
    num_devices: int
    rects_per_device: int
    kmax: int
    leaves_per_device: int

    @property
    def leaf_bytes(self) -> int:
        return self.leaf_rects_flat.nbytes

    @property
    def header_bytes(self) -> int:
        return self.cover_mbrs.nbytes // self.num_devices  # broadcast once


def shard_tree(tree: SerializedRTree, num_devices: int) -> ShardedLayout:
    """Partition the BFS leaf level into contiguous per-device slices and
    compute each device's covering level-1 MBR neighborhood."""
    d = int(num_devices)
    leaf_rects = np.asarray(tree.leaf_rects)           # (L, B, 4)
    l, b, _ = leaf_rects.shape
    lp = math.ceil(l / d)
    pad = d * lp - l
    if pad:
        leaf_rects = np.concatenate(
            [leaf_rects, np.tile(EMPTY_RECT, (pad, b, 1))], axis=0
        )
    flat = leaf_rects.reshape(d * lp * b, 4)

    starts = np.asarray(tree.l1_child_start, dtype=np.int64)
    counts = np.asarray(tree.l1_child_count, dtype=np.int64)
    ends = starts + counts
    l1_mbrs = np.asarray(tree.l1_mbrs)
    covers = []
    for dev in range(d):
        s, e = dev * lp, min((dev + 1) * lp, l)
        # level-1 nodes whose child leaf range intersects [s, e)
        hit = (starts < e) & (ends > s)
        covers.append(l1_mbrs[hit])
    kmax = max(1, max(c.shape[0] for c in covers))
    cover_mbrs = np.tile(EMPTY_RECT, (d, kmax, 1))
    for dev, c in enumerate(covers):
        cover_mbrs[dev, : c.shape[0]] = c
    return ShardedLayout(
        leaf_rects_flat=flat.astype(np.int32),
        cover_mbrs=cover_mbrs.astype(np.int32),
        num_devices=d,
        rects_per_device=lp * b,
        kmax=kmax,
        leaves_per_device=lp,
    )


def make_query_step(
    mesh: jax.sharding.Mesh,
    *,
    impl: str = ops.DEFAULT_IMPL,
    tq: int = 512,
    tr: int = 1024,
):
    """Build the jitted SPMD query step for ``mesh``.

    Returns ``step(leaf_rects_flat, cover_mbrs, queries) -> counts`` where
    the leaf array is sharded over all mesh axes, headers are sharded
    one-row-per-device, and queries/counts are replicated.  This function is
    what the multi-pod dry-run lowers and compiles.
    """
    axes = tuple(mesh.axis_names)
    p_leaf = jax.sharding.PartitionSpec(axes)
    p_cover = jax.sharding.PartitionSpec(axes)
    p_rep = jax.sharding.PartitionSpec()

    def shard_fn(local_rects, local_cover, queries):
        cover = local_cover.reshape(-1, 4)              # (Kmax, 4)
        # Phase 1: upper-level filtering against the covering L1 MBRs
        # (WRAM-resident metadata in the paper; VMEM/registers here).
        m = kref.rect_overlap(queries[:, None, :], cover[None, :, :])
        mask = m.any(axis=1)
        # Phase 2: local leaf scan with tile-MBR pruning.
        counts = ops.overlap_counts(
            queries, local_rects, mask, impl=impl, tq=tq, tr=tr
        )
        # Host aggregation in the paper; on-fabric psum here.
        return jax.lax.psum(counts, axes)

    fn = jax.shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(p_leaf, p_cover, p_rep),
        out_specs=p_rep,
        check_vma=False,  # Pallas calls don't carry varying-mesh-axis info
    )
    return jax.jit(fn)


def morton_order(rects: np.ndarray, shift: int = 12) -> np.ndarray:
    """Morton (Z-curve) ordering of rect centres — beyond-paper §Perf S2:
    spatially coherent query batches make query-tile MBRs tight, so the
    kernel's tile-MBR pruning (and the scalar-prefetch kernel's DMA skip)
    fires; measured 6.7× fewer active (query-tile × rect-tile) pairs on the
    lakes workload vs arrival order."""
    r = rects.astype(np.int64)
    cx = (((r[:, 0] + r[:, 2]) // 2) >> shift).astype(np.uint64)
    cy = (((r[:, 1] + r[:, 3]) // 2) >> shift).astype(np.uint64)
    code = np.zeros(len(rects), np.uint64)
    for i in range(10):
        code |= ((cx >> np.uint64(i)) & np.uint64(1)) << np.uint64(2 * i)
        code |= ((cy >> np.uint64(i)) & np.uint64(1)) << np.uint64(2 * i + 1)
    return np.argsort(code, kind="stable")


class BroadcastEngine:
    """End-to-end broadcast engine: host build → device placement → batched
    queries.  Mirrors the paper's Fig. 3 workflow.  ``sort_queries`` applies
    Morton ordering per batch (counts are un-permuted on return)."""

    def __init__(
        self,
        tree: SerializedRTree,
        mesh: jax.sharding.Mesh,
        *,
        impl: str = ops.DEFAULT_IMPL,
        tq: int = 512,
        tr: int = 1024,
        batch_size: int = DEFAULT_BATCH,
        sort_queries: bool = False,
    ):
        self.mesh = mesh
        self.batch_size = int(batch_size)
        self.sort_queries = sort_queries
        self.num_devices = _mesh_device_count(mesh)
        self.layout = shard_tree(tree, self.num_devices)

        axes = tuple(mesh.axis_names)
        leaf_sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec(axes))
        rep_sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
        # one-time placement: leaf scatter + header broadcast (paper Sec III-C.3)
        self.leaf_rects = jax.device_put(self.layout.leaf_rects_flat, leaf_sh)
        self.cover_mbrs = jax.device_put(self.layout.cover_mbrs, leaf_sh)
        self._rep_sh = rep_sh
        self._step = make_query_step(mesh, impl=impl, tq=tq, tr=tr)

    def query(self, queries: np.ndarray) -> np.ndarray:
        """Batched range-query counts (paper Sec III-C.4/5)."""
        queries = np.asarray(queries, dtype=np.int32)
        if self.sort_queries:
            order = morton_order(queries)
            inv = np.argsort(order, kind="stable")
            return self._query_inner(queries[order])[inv]
        return self._query_inner(queries)

    def _query_inner(self, queries: np.ndarray) -> np.ndarray:
        q = queries.shape[0]
        bs = self.batch_size
        out = np.empty(q, dtype=np.int32)
        for lo in range(0, q, bs):
            hi = min(lo + bs, q)
            batch = queries[lo:hi]
            if hi - lo < bs:  # pad the tail batch to keep one compiled shape
                batch = np.concatenate(
                    [batch, np.tile(EMPTY_RECT, (bs - (hi - lo), 1))]
                )
            dev_batch = jax.device_put(batch, self._rep_sh)
            counts = self._step(self.leaf_rects, self.cover_mbrs, dev_batch)
            out[lo:hi] = np.asarray(counts)[: hi - lo]
        return out

    # ---- communication-volume model (paper Figs. 7/10, Table III) --------
    def transfer_stats(self, num_queries: int) -> dict[str, int]:
        """Bytes moved host→device / device→host under the paper's model.

        broadcast: headers once; leaves scatter once; queries broadcast per
        batch; results one count per query (fabric-reduced)."""
        nb = math.ceil(num_queries / self.batch_size)
        return {
            "header_broadcast_bytes": self.layout.header_bytes,
            "leaf_scatter_bytes": self.layout.leaf_bytes,
            "query_broadcast_bytes": nb * self.batch_size * 16,
            "result_bytes": num_queries * 4,
            "per_batch_bytes": self.batch_size * 16,
        }
