"""Multi-threaded CPU baseline (paper Algorithm 1).

Performs R-tree range queries entirely in host memory against the *same*
serialized STR tree the PIM engines use ("the CPU baseline uses the same
R-tree structure ... constructed on the host with identical bulk-loading
parameters").  Query processing uses dynamic, chunk-based scheduling over a
shared atomic index to mitigate load imbalance from spatial skew, exactly as
Algorithm 1 prescribes.  The tree is read-only during queries, so traversal
needs no synchronisation.

Python threads do not give CPU parallelism (GIL), but numpy releases the GIL
inside vectorised kernels, so the chunked traversal below does overlap work
across threads; more importantly the *scheduling semantics* (atomic
fetch-and-add over chunks) are reproduced faithfully and unit-tested.
"""
from __future__ import annotations

import itertools
import threading

import numpy as np

from repro.core.types import SerializedRTree, TopDownNode, rect_overlap_np


def search_serialized(tree: SerializedRTree, query: np.ndarray) -> int:
    """SEARCHR-TREE for the 3-level serialized tree: root → level-1 pruning →
    leaf MBR pruning → exact rect tests.  Returns the overlap count."""
    if not rect_overlap_np(np.asarray(tree.root_mbr), query):
        return 0
    l1_hit = rect_overlap_np(np.asarray(tree.l1_mbrs), query)
    total = 0
    starts = np.asarray(tree.l1_child_start)
    counts = np.asarray(tree.l1_child_count)
    leaf_mbrs = np.asarray(tree.leaf_mbrs)
    leaf_rects = np.asarray(tree.leaf_rects)
    for i in np.nonzero(l1_hit)[0]:
        lo, hi = int(starts[i]), int(starts[i] + counts[i])
        leaf_hit = rect_overlap_np(leaf_mbrs[lo:hi], query)
        for j in np.nonzero(leaf_hit)[0]:
            rects = leaf_rects[lo + j]
            total += int(rect_overlap_np(rects, query).sum())
    return total


def search_topdown(node: TopDownNode, query: np.ndarray) -> int:
    """Recursive traversal of the fanout-constrained top-down tree."""
    if not rect_overlap_np(node.mbr, query):
        return 0
    if node.is_leaf:
        return int(rect_overlap_np(node.rects, query).sum())
    return sum(search_topdown(c, query) for c in node.children)


def parallel_query(
    tree: SerializedRTree,
    queries: np.ndarray,
    num_threads: int = 8,
    chunk_size: int = 64,
) -> np.ndarray:
    """Algorithm 1: dynamic chunked parallel query processing.

    A shared atomic index hands out chunks of ``chunk_size`` queries; each
    thread loops fetch-and-add → process until the query set is exhausted.
    """
    queries = np.asarray(queries, dtype=np.int32)
    n = queries.shape[0]
    results = np.zeros(n, dtype=np.int32)
    counter = itertools.count(0)          # atomic via CPython GIL
    lock = threading.Lock()

    def fetch_and_add() -> int:
        with lock:
            return next(counter) * chunk_size

    def worker():
        while True:
            start = fetch_and_add()
            if start >= n:
                break
            end = min(start + chunk_size, n)
            for i in range(start, end):
                results[i] = search_serialized(tree, queries[i])

    threads = [threading.Thread(target=worker) for _ in range(num_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return results


def sequential_query(tree: SerializedRTree, queries: np.ndarray) -> np.ndarray:
    """CPU-seq baseline: single-threaded traversal."""
    queries = np.asarray(queries, dtype=np.int32)
    return np.array(
        [search_serialized(tree, q) for q in queries], dtype=np.int32
    )
