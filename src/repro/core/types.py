"""Core datatypes for the PIM R-tree engines.

Coordinates are fixed-precision int32 throughout, matching the paper's
conversion of all datasets to 32-bit integers ("UPMEM PIM hardware ... does
not efficiently support floating-point operations"). A rectangle is a row
``[xmin, ymin, xmax, ymax]``; two rectangles overlap iff their closed
intervals intersect in both dimensions. Empty/padding slots use a sentinel
rectangle with ``xmin > xmax`` so every overlap test against it fails.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np

INT32_MAX = np.int32(2**31 - 1)
INT32_MIN = np.int32(-(2**31))

# Sentinel rectangle: fails every overlap test (xmin > xmax, ymin > ymax).
EMPTY_RECT = np.array([INT32_MAX, INT32_MAX, INT32_MIN, INT32_MIN], dtype=np.int32)


def rect_overlap_np(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Vectorised closed-interval overlap test between broadcastable rect arrays."""
    return (
        (a[..., 0] <= b[..., 2])
        & (b[..., 0] <= a[..., 2])
        & (a[..., 1] <= b[..., 3])
        & (b[..., 1] <= a[..., 3])
    )


def mbr_of(rects: np.ndarray) -> np.ndarray:
    """Minimum bounding rectangle(s) over the second-to-last axis of a
    (..., N, 4) rect array.

    EMPTY sentinels are identity elements of the reduction (INT32_MAX minima
    / INT32_MIN maxima), so sentinel-padded groups yield exact MBRs as long
    as each group has at least one valid rect; an all-sentinel group yields
    the EMPTY MBR.  This is the one MBR reduction every builder (STR levels,
    shard_tree tile cache, subtree tile cache) shares with the kernels'
    device twin (``ops.tile_mbrs``)."""
    return np.concatenate(
        [rects[..., :2].min(axis=-2), rects[..., 2:].max(axis=-2)], axis=-1
    ).astype(np.int32)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SerializedRTree:
    """Exactly-three-level STR R-tree in breadth-first, pointer-free layout.

    Structure-of-arrays form of the paper's ``SN`` record array: the BFS index
    of the root is 0, level-1 node ``i`` is ``1 + i``, and leaf ``j`` is
    ``1 + num_l1 + j`` — so the leaf level begins at ``1 + root.count``, as in
    the paper (Section III-C.2). Children of level-1 node ``i`` are the
    contiguous leaf range ``[l1_child_start[i], l1_child_start[i] +
    l1_child_count[i])``, which is what makes contiguous leaf slicing across
    devices equivalent to the paper's per-DPU leaf partitions.
    """

    root_mbr: Any      # (4,) int32
    l1_mbrs: Any       # (C1, 4) int32
    l1_child_start: Any  # (C1,) int32 — first leaf index of the child range
    l1_child_count: Any  # (C1,) int32
    leaf_mbrs: Any     # (L, 4) int32
    leaf_counts: Any   # (L,) int32 — valid rects per leaf
    leaf_rects: Any    # (L, B, 4) int32, padded with EMPTY_RECT
    # Source IDs of the packed rects: leaf_ids[j, s] is the index of
    # leaf_rects[j, s] in the *input* rect array of the build (-1 for EMPTY
    # padding slots).  Result materialization (repro.query) returns these, so
    # IDs survive the STR permutation.  None on hand-built trees: consumers
    # fall back to BFS-packed positional IDs.
    leaf_ids: Any = None   # (L, B) int32 or None

    def tree_flatten(self):
        children = (
            self.root_mbr,
            self.l1_mbrs,
            self.l1_child_start,
            self.l1_child_count,
            self.leaf_mbrs,
            self.leaf_counts,
            self.leaf_rects,
            self.leaf_ids,
        )
        return children, None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    @property
    def num_l1(self) -> int:
        return self.l1_mbrs.shape[0]

    @property
    def num_leaves(self) -> int:
        return self.leaf_mbrs.shape[0]

    @property
    def leaf_capacity(self) -> int:
        return self.leaf_rects.shape[1]

    @property
    def num_rects(self) -> int:
        return int(np.asarray(self.leaf_counts).sum())

    def total_bytes(self) -> int:
        """Serialized size — used by the communication-volume model.

        ``leaf_ids`` is excluded: the paper's SN records carry no source-ID
        column, and the communication model tracks the index broadcast only
        (IDs are scattered once with the leaf payload by the query
        subsystem and accounted there)."""
        return sum(
            int(np.asarray(x).size) * 4
            for x in (
                self.root_mbr, self.l1_mbrs, self.l1_child_start,
                self.l1_child_count, self.leaf_mbrs, self.leaf_counts,
                self.leaf_rects,
            )
        )

    def header_bytes(self) -> int:
        """Bytes of the broadcast prefix (root + level-1 headers only)."""
        return 4 * (4 + self.num_l1 * (4 + 1 + 1))


@dataclasses.dataclass(frozen=True)
class TopDownNode:
    """Node of the fanout-constrained top-down tree (paper Algorithm 2).

    Used by the subtree-partitioned PIM baseline: the root's children are the
    per-DPU subtrees.
    """

    mbr: np.ndarray                  # (4,) int32
    is_leaf: bool
    rects: np.ndarray | None         # (n, 4) for leaves
    children: tuple["TopDownNode", ...] = ()

    def count_nodes(self) -> int:
        return 1 + sum(c.count_nodes() for c in self.children)

    def count_rects(self) -> int:
        if self.is_leaf:
            return len(self.rects)
        return sum(c.count_rects() for c in self.children)

    def serialized_bytes(self) -> int:
        """Approximate serialized size following the paper's SN struct:
        isLeaf + count + MBR + children indices + rect payload."""
        own = 4 * (1 + 1 + 4) + 4 * len(self.children)
        if self.is_leaf:
            own += 16 * len(self.rects)
        return own + sum(c.serialized_bytes() for c in self.children)
