"""Subtree-partitioned PIM baseline engine (paper Section III-B).

Each device is assigned one independent subtree of the fanout-constrained
top-down tree (Algorithm 2): the root's children become per-device subtrees,
each serialized and transferred whole to its device.  Every device evaluates
the complete query set against its local subtree; partial counts are reduced.

On TPU the per-device subtree is serialized as the flat array of its
rectangles (padded to the max across devices — SPMD needs uniform shapes,
and the padding itself is part of the baseline's communication cost, just as
per-DPU serialized subtrees of varying size are in the paper).  Traversal
pruning inside a device uses the subtree root MBR as a single-entry Phase-1
cover (fused into the kernel, DESIGN.md Sec 4) and the kernel's cached
tile-MBR pruning (internal-node equivalent) — the baseline shares the
device-resident pipeline of :mod:`repro.core.engine` so the comparison
isolates the *partitioning strategy*, not the batch plumbing.

The paper's headline finding — the subtree design is *communication
dominated* because each DPU needs a distinct transfer whose aggregate volume
(and per-batch re-staging) scales with device count and query volume — is
reproduced by the transfer model below and measured in
benchmarks/table3_broadcast_vs_subtree.py.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core import rtree
from repro.core.engine import (
    QueryKindMixin, stream_batches, validate_queries)
from repro.core.types import EMPTY_RECT, TopDownNode, mbr_of
from repro.kernels import ops
from repro.obs import phases as obs_phases
from repro.obs import trace as obs_trace


def _collect_rects(node: TopDownNode) -> np.ndarray:
    if node.is_leaf:
        return node.rects
    return np.concatenate([_collect_rects(c) for c in node.children], axis=0)


@dataclasses.dataclass(frozen=True)
class SubtreeLayout:
    rects: np.ndarray          # (D, Rp, 4) int32 EMPTY-padded
    root_mbrs: np.ndarray      # (D, 4) int32 — per-subtree root MBR
    subtree_bytes: np.ndarray  # (D,) int64 — true serialized size per device
    num_devices: int
    tile: int | None = None
    rect_tile_mbrs: np.ndarray | None = None   # (D, NT, 4) int32
    # Source IDs aligned with ``rects`` slots (-1 for EMPTY padding): the
    # index of each placed rect in the *input* array of build_layout, so the
    # query subsystem returns IDs that survive the top-down partitioning.
    rect_ids: np.ndarray | None = None         # (D, Rp) int32

    @property
    def scatter_bytes(self) -> int:
        """Aggregate host→device bytes: every device receives a *distinct*
        serialized subtree (no broadcast reuse)."""
        return int(self.subtree_bytes.sum())

    def fingerprint(self) -> str:
        """Content hash of the placed layout (layout-version handle; same
        contract as :meth:`repro.core.engine.ShardedLayout.fingerprint`)."""
        h = zlib.crc32(np.ascontiguousarray(self.rects).tobytes())
        h = zlib.crc32(np.ascontiguousarray(self.root_mbrs).tobytes(), h)
        return f"{self.num_devices}d-{h:08x}"


def build_layout(
    rects: np.ndarray, num_devices: int, leaf_capacity: int,
    *, tile: int | None = None,
) -> SubtreeLayout:
    with obs_trace.span("build_layout", phase=obs_phases.BUILD,
                        rects=int(np.asarray(rects).shape[0]),
                        devices=int(num_devices), tile=tile):
        return _build_layout_inner(rects, num_devices, leaf_capacity, tile)


def _source_ids(input_rects: np.ndarray, collected: np.ndarray) -> np.ndarray:
    """Match each collected (partitioned) rect back to its input index.

    The top-down build permutes rows without recording the permutation;
    because ``collected`` is exactly a row-permutation of ``input_rects``,
    sorting both lexicographically aligns them.  Duplicate rects are
    assigned their tied source indices deterministically (ascending on both
    sides), which is all the query surface needs: identical coordinates are
    interchangeable under every distance/overlap predicate, and the
    (distance, id) tie-break sees the same id multiset as the oracle.
    """
    inp = np.ascontiguousarray(np.asarray(input_rects, dtype=np.int32))
    coll = np.ascontiguousarray(np.asarray(collected, dtype=np.int32))
    assert inp.shape == coll.shape, (inp.shape, coll.shape)
    in_order = np.lexsort(inp.T[::-1])
    coll_order = np.lexsort(coll.T[::-1])
    ids = np.empty(inp.shape[0], dtype=np.int32)
    ids[coll_order] = in_order.astype(np.int32)
    return ids


def _build_layout_inner(rects, num_devices, leaf_capacity, tile):
    root = rtree.build_fanout_constrained(rects, num_devices, leaf_capacity)
    subs = rtree.subtree_partitions(root, num_devices)
    per_dev = [_collect_rects(s) for s in subs]
    sizes = [r.shape[0] for r in per_dev]
    all_ids = _source_ids(rects, np.concatenate(per_dev, axis=0))
    rmax = max(sizes)
    if tile is not None:
        rmax = math.ceil(rmax / tile) * tile
    d = num_devices
    out = np.tile(EMPTY_RECT, (d, rmax, 1))
    out_ids = np.full((d, rmax), -1, dtype=np.int32)
    mbrs = np.tile(EMPTY_RECT, (d, 1))
    # byte counter, not an index — a true 64-bit payload
    sbytes = np.zeros(d, dtype=np.int64)    # pallint: disable=PL109
    id_lo = 0
    for i, r in enumerate(per_dev):
        out[i, : r.shape[0]] = r
        out_ids[i, : r.shape[0]] = all_ids[id_lo: id_lo + r.shape[0]]
        id_lo += r.shape[0]
        mbrs[i] = subs[i].mbr
        sbytes[i] = subs[i].serialized_bytes()
    rect_tile_mbrs = None
    if tile is not None:
        rect_tile_mbrs = mbr_of(out.reshape(d, rmax // tile, tile, 4))
        # dtype-consistency contract (pallint PL109 doctrine): everything
        # device-placed is int32 — coordinates, MBRs, and tile metadata.
        assert rect_tile_mbrs.dtype == np.int32, rect_tile_mbrs.dtype
    for r in per_dev:
        assert r.dtype == np.int32, r.dtype
    return SubtreeLayout(
        rects=out.astype(np.int32),
        root_mbrs=mbrs.astype(np.int32),
        subtree_bytes=sbytes,
        num_devices=d,
        tile=tile,
        rect_tile_mbrs=rect_tile_mbrs,
        rect_ids=out_ids,
    )


def make_query_step(
    mesh: jax.sharding.Mesh,
    *,
    impl: str = ops.DEFAULT_IMPL,
    tq: int = 512,
    tr: int = 1024,
    donate_queries: bool = True,
    on_trace: Callable[[], None] | None = None,
):
    axes = tuple(mesh.axis_names)
    p_coords = jax.sharding.PartitionSpec(None, axes)
    p_shard = jax.sharding.PartitionSpec(axes)
    p_rep = jax.sharding.PartitionSpec()

    def shard_fn(local_coords, local_rmbrs, local_root_mbr, queries):
        if on_trace is not None:
            on_trace()
        # subtree root MBR = a one-entry Phase-1 cover set (recursion step 0
        # in the paper's DPU code), fused into the kernel like the broadcast
        # engine's L1 covers
        cover = local_root_mbr.reshape(-1, 4)           # (1, 4)
        rmbrs = local_rmbrs.reshape(-1, 4)
        counts = ops.overlap_counts_fused(
            queries, local_coords, rmbrs, cover, impl=impl, tq=tq, tr=tr
        )
        return jax.lax.psum(counts, axes)

    fn = compat.shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(p_coords, p_shard, p_shard, p_rep),
        out_specs=p_rep,
        check_vma=False,  # Pallas calls don't carry varying-mesh-axis info
    )
    return jax.jit(fn, donate_argnums=(3,) if donate_queries else ())


class SubtreeEngine(QueryKindMixin):
    """Baseline PIM R-tree engine: one subtree per device."""

    def __init__(
        self,
        rects: np.ndarray,
        mesh: jax.sharding.Mesh,
        *,
        leaf_capacity: int,
        impl: str = ops.DEFAULT_IMPL,
        tq: int = 512,
        tr: int = 1024,
        batch_size: int = 10_000,
    ):
        self.mesh = mesh
        self.batch_size = int(batch_size)
        d = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
        self.num_devices = d
        self.layout = build_layout(rects, d, leaf_capacity, tile=tr)
        self.trace_count = 0
        self._impl, self._tq, self._tr = impl, tq, tr
        self._kind_steps = {}

        axes = tuple(mesh.axis_names)
        coords_sh = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(None, axes))
        shard_sh = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(axes))
        self._rep_sh = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec())
        with obs_trace.span(
                "place", phase=obs_phases.H2D,
                scatter_bytes=int(self.layout.scatter_bytes)):
            self.dev_coords = jax.device_put(
                np.ascontiguousarray(self.layout.rects.reshape(-1, 4).T),
                coords_sh)
            self.dev_tile_mbrs = jax.device_put(
                self.layout.rect_tile_mbrs, shard_sh)
            self.dev_mbrs = jax.device_put(self.layout.root_mbrs, shard_sh)
            # source IDs ride the same sharding as the subtree slices so the
            # materializing kinds can return them without any host gather
            self.dev_ids = jax.device_put(
                np.ascontiguousarray(self.layout.rect_ids.reshape(-1)),
                shard_sh)
            if obs_trace.enabled():
                # only when tracing: charge the actual transfer to the span,
                # not just the async dispatch
                jax.block_until_ready(             # pallint: disable=PL102
                    (self.dev_coords, self.dev_tile_mbrs, self.dev_mbrs,
                     self.dev_ids))

        def _count_trace():
            self.trace_count += 1

        self._step = make_query_step(
            mesh, impl=impl, tq=tq, tr=tr, on_trace=_count_trace)

    def query(self, queries: np.ndarray) -> np.ndarray:
        with obs_trace.span("subtree.query", phase=obs_phases.HOST,
                            queries=int(np.asarray(queries).shape[0])):
            queries = validate_queries(queries, where="SubtreeEngine.query")
            return stream_batches(
                self._step,
                (self.dev_coords, self.dev_tile_mbrs, self.dev_mbrs),
                queries, self.batch_size, self._rep_sh,
            )

    # ---- query-kind surface (QueryKindMixin) -----------------------------
    def _kind_operands(self):
        return (self.dev_coords, self.dev_ids, self.dev_tile_mbrs,
                self.dev_mbrs)

    @property
    def placed_rects(self) -> np.ndarray:
        """(N, 4) host copy of the placed subtree rects in device order."""
        return self.layout.rects.reshape(-1, 4)

    @property
    def placed_ids(self) -> np.ndarray:
        """(N,) source IDs aligned with :attr:`placed_rects` (-1 padding)."""
        return self.layout.rect_ids.reshape(-1)

    def transfer_stats(self, num_queries: int) -> dict[str, int]:
        """The paper observed "repeated subtree transfers and per-DPU data
        movement" growing with query volume: subtrees are re-staged per
        query batch in the baseline implementation.  Modeled accordingly."""
        nb = math.ceil(num_queries / self.batch_size)
        return {
            "subtree_scatter_bytes": self.layout.scatter_bytes,
            "per_batch_restage_bytes": self.layout.scatter_bytes,
            "total_scatter_bytes": nb * self.layout.scatter_bytes,
            "query_broadcast_bytes": nb * self.batch_size * 16,
            "result_bytes": num_queries * 4,
        }
