"""Host-side R-tree construction, exactly as in the paper.

Two builders:

* :func:`build_str_3level` — bottom-up Sort-Tile-Recursive (STR) bulk loading
  (Leutenegger et al.) constrained to exactly three levels (root, level-1
  internal nodes, leaves), serialized breadth-first into a pointer-free
  structure-of-arrays (:class:`~repro.core.types.SerializedRTree`).  This is
  the index used by the Broadcast PIM engine (paper Section III-C).

* :func:`build_fanout_constrained` — the paper's Algorithm 2: a top-down,
  STR-inspired recursive build whose *root* fanout is capped at the number of
  devices so each root child becomes one per-device subtree.  Used by the
  subtree-partitioned baseline (paper Section III-B).

Construction is a host-side, one-time preprocessing cost (numpy), exactly as
the paper performs it on the CPU before transferring to DPUs.
"""
from __future__ import annotations

import math

import numpy as np

from repro.core.types import EMPTY_RECT, SerializedRTree, TopDownNode, mbr_of
from repro.obs import phases as obs_phases
from repro.obs import trace as obs_trace


def _validate_rects(rects: np.ndarray) -> np.ndarray:
    rects = np.asarray(rects, dtype=np.int32)
    if rects.ndim != 2 or rects.shape[1] != 4:
        raise ValueError(f"rects must be (N, 4), got {rects.shape}")
    if rects.shape[0] == 0:
        raise ValueError("cannot build an R-tree over zero rectangles")
    bad = (rects[:, 0] > rects[:, 2]) | (rects[:, 1] > rects[:, 3])
    if bad.any():
        raise ValueError(f"{int(bad.sum())} rects have min > max")
    return rects


def _centers(rects: np.ndarray) -> np.ndarray:
    # Midpoints; int64 intermediate avoids overflow on extreme coordinates.
    r = rects.astype(np.int64)    # pallint: disable=PL109
    return np.stack([(r[:, 0] + r[:, 2]) // 2, (r[:, 1] + r[:, 3]) // 2], axis=1)


def str_pack(rects: np.ndarray, capacity: int) -> np.ndarray:
    """One STR packing pass: returns ``order`` such that consecutive groups of
    ``capacity`` rows of ``rects[order]`` form the packed nodes.

    Sort by x-centre, cut into ``ceil(sqrt(ceil(N/capacity)))`` vertical
    slices of whole nodes, then sort each slice by y-centre (paper
    Section III-C.1).
    """
    n = rects.shape[0]
    num_nodes = math.ceil(n / capacity)
    num_slices = math.ceil(math.sqrt(num_nodes))
    slice_rects = math.ceil(num_nodes / num_slices) * capacity

    c = _centers(rects)
    by_x = np.argsort(c[:, 0], kind="stable")
    # permutation indices follow the 32-bit index doctrine (pallint PL109)
    order = np.empty(n, dtype=np.int32)
    for s in range(num_slices):
        lo, hi = s * slice_rects, min((s + 1) * slice_rects, n)
        if lo >= hi:
            continue
        idx = by_x[lo:hi]
        by_y = np.argsort(c[idx, 1], kind="stable")
        order[lo:hi] = idx[by_y]
    return order


def choose_parameters(n: int, num_devices: int) -> tuple[int, int]:
    """Pick (BUNDLEFACTOR, FANOUT) giving exactly three levels with at least
    one leaf per device and a compact broadcast prefix.

    The paper selects B and F "such that the resulting R-tree has exactly
    three levels" with the upper two levels small enough to broadcast into
    WRAM.  We target: leaves L = ceil(N/B) >= num_devices (so the contiguous
    leaf partition gives every device work) and level-1 count
    C1 = ceil(L/F) in the low hundreds (compact replicated header).
    """
    b = max(1, min(256, math.ceil(n / max(num_devices * 8, 64))))
    b = min(b, max(1, n // num_devices))  # leaves >= num_devices when n allows
    leaves = math.ceil(n / b)
    f = max(2, math.ceil(leaves / 256))
    if math.ceil(leaves / f) < 1:
        f = leaves
    return b, f


def build_str_3level(
    rects: np.ndarray, leaf_capacity: int, fanout: int
) -> SerializedRTree:
    """Bottom-up STR bulk load into an exactly-three-level tree, BFS-serialized.

    Leaf level: STR pack rects with capacity ``leaf_capacity`` (B).
    Level 1:    STR pack leaf MBRs with capacity ``fanout`` (F).
    Root:       single node over all level-1 MBRs.

    The returned SoA is the breadth-first serialization: level-1 nodes in
    packed order, then all leaves; children of level-1 node ``i`` are the
    contiguous leaf range starting at ``l1_child_start[i]`` — the layout the
    paper broadcasts (prefix) and partitions (leaf level).
    """
    with obs_trace.span("build_str_3level", phase=obs_phases.BUILD,
                        rects=int(np.asarray(rects).shape[0]),
                        leaf_capacity=int(leaf_capacity),
                        fanout=int(fanout)):
        return _build_str_3level_inner(rects, leaf_capacity, fanout)


def _build_str_3level_inner(rects, leaf_capacity, fanout):
    rects = _validate_rects(rects)
    n = rects.shape[0]
    b, f = int(leaf_capacity), int(fanout)
    if b < 1 or f < 1:
        raise ValueError("leaf_capacity and fanout must be positive")

    # --- leaf level ---------------------------------------------------------
    # STR packing fills leaves front-to-back, so only the last leaf can be
    # partial: pad the packed rows with EMPTY sentinels and reshape — no
    # Python loop over the (possibly millions of) leaves.  Sentinels carry
    # INT32_MAX minima / INT32_MIN maxima, so the min/max reductions below
    # give exact MBRs without masking.
    order = str_pack(rects, b)
    packed = rects[order]
    # Source IDs ride along with the packed rects so result materialization
    # can return indices into the *input* array (-1 marks padding).
    packed_ids = order.astype(np.int32)
    num_leaves = math.ceil(n / b)
    pad = num_leaves * b - n
    if pad:
        packed = np.concatenate([packed, np.tile(EMPTY_RECT, (pad, 1))])
        packed_ids = np.concatenate(
            [packed_ids, np.full(pad, -1, dtype=np.int32)])
    leaf_rects = packed.reshape(num_leaves, b, 4)
    leaf_ids = packed_ids.reshape(num_leaves, b)
    leaf_counts = np.full(num_leaves, b, dtype=np.int32)
    leaf_counts[-1] = b - pad
    assert (leaf_counts > 0).all(), "STR packing must not create empty leaves"
    leaf_mbrs = mbr_of(leaf_rects)

    # --- level 1: STR over leaf MBRs ---------------------------------------
    l1_order = str_pack(leaf_mbrs, f)
    # Re-order the leaf level so each level-1 node's children are contiguous
    # in the serialized leaf array (BFS contiguity).
    leaf_rects = leaf_rects[l1_order]
    leaf_counts = leaf_counts[l1_order]
    leaf_mbrs = leaf_mbrs[l1_order]
    leaf_ids = leaf_ids[l1_order]

    num_l1 = math.ceil(num_leaves / f)
    l1_child_start = (np.arange(num_l1, dtype=np.int32) * f).astype(np.int32)
    l1_child_count = np.minimum(f, num_leaves - l1_child_start).astype(
        np.int32)
    pad_l1 = num_l1 * f - num_leaves
    lm = leaf_mbrs
    if pad_l1:
        lm = np.concatenate([lm, np.tile(EMPTY_RECT, (pad_l1, 1))])
    l1_mbrs = mbr_of(lm.reshape(num_l1, f, 4))

    root_mbr = mbr_of(l1_mbrs)
    return SerializedRTree(
        root_mbr=root_mbr,
        l1_mbrs=l1_mbrs,
        l1_child_start=l1_child_start,
        l1_child_count=l1_child_count,
        leaf_mbrs=leaf_mbrs,
        leaf_counts=leaf_counts,
        leaf_rects=leaf_rects,
        leaf_ids=leaf_ids,
    )


def to_sn_records(tree: SerializedRTree) -> np.ndarray:
    """Flatten to the paper's literal SN record layout for fidelity tests.

    Record: [isLeaf, count, mbr(4), children(F) or first rect coords…] — we
    emit a structured array with separate fields instead of a byte blob, in
    BFS order: root, level-1 nodes, leaves.  ``leaf level start == 1 +
    SN[0].count`` holds by construction.
    """
    f = int(tree.l1_child_count.max()) if tree.num_l1 else 0
    b = tree.leaf_capacity
    width = max(f, tree.num_l1, 1)  # root fanout may exceed F
    dtype = np.dtype(
        [
            ("isLeaf", np.int32),
            ("count", np.int32),
            ("mbr", np.int32, (4,)),
            ("children", np.int32, (width,)),
            ("rects", np.int32, (max(b, 1), 4)),
        ]
    )
    k = 1 + tree.num_l1 + tree.num_leaves
    sn = np.zeros(k, dtype=dtype)
    leaf_base = 1 + tree.num_l1
    # root: children are the level-1 node indices 1..num_l1.
    sn[0]["isLeaf"] = 0
    sn[0]["count"] = tree.num_l1
    sn[0]["mbr"] = tree.root_mbr
    sn[0]["children"][: tree.num_l1] = 1 + np.arange(tree.num_l1)
    for i in range(tree.num_l1):
        rec = sn[1 + i]
        rec["isLeaf"] = 0
        rec["count"] = tree.l1_child_count[i]
        rec["mbr"] = tree.l1_mbrs[i]
        cs = int(tree.l1_child_start[i])
        cc = int(tree.l1_child_count[i])
        rec["children"][:cc] = leaf_base + cs + np.arange(cc)
    for j in range(tree.num_leaves):
        rec = sn[leaf_base + j]
        rec["isLeaf"] = 1
        rec["count"] = tree.leaf_counts[j]
        rec["mbr"] = tree.leaf_mbrs[j]
        rec["rects"][: b or 1] = tree.leaf_rects[j]
    return sn


# ---------------------------------------------------------------------------
# Paper Algorithm 2: fanout-constrained top-down build (subtree baseline).
# ---------------------------------------------------------------------------


def build_fanout_constrained(
    rects: np.ndarray, num_devices: int, leaf_capacity: int
) -> TopDownNode:
    """Fanout-constrained R-tree creation (paper Algorithm 2).

    ``k = min(P, ceil(|R|/B))`` children at every internal node; groups formed
    by x-centre slabs then y-centre partitioning (STR-style spatial ordering).
    The root's children are assigned one-subtree-per-device by the subtree
    baseline engine.
    """
    rects = _validate_rects(rects)
    b, p = int(leaf_capacity), int(num_devices)

    def build(r: np.ndarray) -> TopDownNode:
        if r.shape[0] <= b:
            return TopDownNode(mbr=mbr_of(r), is_leaf=True, rects=r)
        k = min(p, math.ceil(r.shape[0] / b))
        if k <= 1:
            # degenerate fanout (P == 1): the subtree is a single flat leaf
            return TopDownNode(mbr=mbr_of(r), is_leaf=True, rects=r)
        num_slabs = math.ceil(math.sqrt(k))
        # distribute exactly k groups across slabs (sum over slabs == k)
        base, rem = divmod(k, num_slabs)
        slab_groups = [base + (1 if s < rem else 0) for s in range(num_slabs)]
        c = _centers(r)
        by_x = np.argsort(c[:, 0], kind="stable")
        children = []
        pos = 0
        n_r = r.shape[0]
        for s in range(num_slabs):
            # slab size proportional to its group share
            take = math.ceil(n_r * slab_groups[s] / k)
            idx = by_x[pos : min(pos + take, n_r)]
            pos += take
            if idx.size == 0:
                continue
            by_y = idx[np.argsort(c[idx, 1], kind="stable")]
            group_size = math.ceil(by_y.size / slab_groups[s])
            for g in range(slab_groups[s]):
                gidx = by_y[g * group_size : (g + 1) * group_size]
                if gidx.size == 0:
                    continue
                children.append(build(r[gidx]))
        return TopDownNode(
            mbr=mbr_of(np.stack([ch.mbr for ch in children])),
            is_leaf=False,
            rects=None,
            children=tuple(children),
        )

    return build(rects)


def subtree_partitions(root: TopDownNode, num_devices: int) -> list[TopDownNode]:
    """Assign the root's children one-per-device (paper Algorithm 2, line 12).

    If the tree has fewer root children than devices, the trailing devices get
    empty placeholder subtrees (they simply report zero counts), mirroring
    idle DPUs.
    """
    subs = list(root.children) if not root.is_leaf else [root]
    if len(subs) > num_devices:
        raise ValueError(
            f"root fanout {len(subs)} exceeds device count {num_devices}; "
            "build with num_devices >= root fanout"
        )
    return subs
