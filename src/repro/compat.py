"""Version-portability shims for the jax APIs this repo targets.

The codebase is written against the current jax surface (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, differentiable
``jax.lax.optimization_barrier``).  Container images often pin an older jax
where those names either don't exist or lack rules; everything here degrades
gracefully so the same source runs on both.

All mesh/shard_map construction in the repo goes through this module — do not
call ``jax.shard_map`` / ``jax.make_mesh`` directly.
"""
from __future__ import annotations

from typing import Callable, Iterable, Sequence

import jax

_HAS_NEW_SHARD_MAP = hasattr(jax, "shard_map")
_HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")

if not _HAS_NEW_SHARD_MAP:  # old home of shard_map
    from jax.experimental import shard_map as _esm


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where supported."""
    if _HAS_AXIS_TYPES:
        return jax.make_mesh(
            tuple(shape), tuple(axes),
            axis_types=(jax.sharding.AxisType.Auto,) * len(tuple(axes)))
    return jax.make_mesh(tuple(shape), tuple(axes))


def shard_map(
    f: Callable,
    mesh: jax.sharding.Mesh,
    in_specs,
    out_specs,
    *,
    axis_names: Iterable[str] | None = None,
    check_vma: bool = False,
):
    """Portable shard_map.

    ``axis_names`` is the set of *manual* axes (new-API semantics); ``None``
    means all mesh axes are manual.  ``check_vma`` maps to the old API's
    ``check_rep``.
    """
    if _HAS_NEW_SHARD_MAP:
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma, **kw)
    auto = frozenset()
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _esm.shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma, auto=auto)


def axis_size(axis_name) -> int:
    """``jax.lax.axis_size`` fallback for jax versions without it."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def _barrier_is_differentiable() -> bool:
    import jax.numpy as jnp

    try:
        jax.grad(lambda x: jax.lax.optimization_barrier(x).sum())(
            jnp.ones((2,)))
        return True
    except NotImplementedError:
        return False


if _barrier_is_differentiable():
    opt_barrier = jax.lax.optimization_barrier
else:
    # Older jax has no differentiation rule for optimization_barrier; the
    # barrier is an XLA scheduling hint with identity semantics, so a
    # straight-through gradient is exact.
    @jax.custom_vjp
    def opt_barrier(x):
        return jax.lax.optimization_barrier(x)

    def _opt_barrier_fwd(x):
        return opt_barrier(x), None

    def _opt_barrier_bwd(_, g):
        return (g,)

    opt_barrier.defvjp(_opt_barrier_fwd, _opt_barrier_bwd)
