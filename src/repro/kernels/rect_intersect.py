"""Pallas TPU kernels for batched rectangle-overlap counting.

This is the compute hot spot of the paper's DPU kernel (Algorithm 3 Phase 2:
"scan leaf nodes in L_d (MRAM) and count overlaps").  On a DPU the scan is a
scalar loop streaming rectangles from MRAM at ~0.6 integer-ops per byte; on
TPU we re-tile it as a (Q_tile × R_tile) overlap-count "matmul" so each query
tile and rect tile loaded into VMEM is reused TR and TQ times respectively —
arithmetic intensity grows with the tile sizes, which is the TPU-native
rethink of the paper's streaming kernel (DESIGN.md Sec 6).

Layout: coordinates travel as (4, N) int32 arrays (rows = xmin, ymin, xmax,
ymax) so a block is a (4, T) VMEM tile with the long dimension on lanes.

Hierarchical pruning: the engine precomputes per-tile MBRs for both operands.
A grid step whose rect-tile MBR does not overlap its query-tile MBR skips all
compute (``@pl.when``) — the tile-granular analogue of not descending an
R-tree subtree.  The scalar-prefetch variant (``overlap_counts_sparse``)
additionally skips the *DMA* of dead tiles via an active tile list; it is the
§Perf hillclimb kernel.

Fused Phase-1 (DESIGN.md Sec 4): the ``*_fused`` kernels take the device's
covering level-1 MBRs directly and evaluate the paper's upper-level filter
*inside* the kernel — a tile-level gate (skip the whole (TQ × TR) step when
the query-tile MBR misses every cover MBR) plus a per-query gate folded into
the count accumulation.  This removes the separate (Q, Kmax) boolean
broadcast the engine used to materialize per batch.

Grid: ``(num_query_tiles, num_rect_tiles)``; the rect axis is the reduction
axis — counts accumulate into the (TQ,) output block, initialised at j == 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Default tile sizes: (TQ, TR) working set = TQ*TR int32 partials plus two
# (4, T) coordinate tiles.  512×1024 → 2 MB of bool partials + 24 KB coords,
# comfortably inside a v5e core's ~128 MB VMEM with double buffering.
DEFAULT_TQ = 512
DEFAULT_TR = 1024


def _tile_overlap(qmbr, rmbr):
    """Scalar overlap test between two MBR vectors of shape (4,)."""
    return (
        (qmbr[0] <= rmbr[2])
        & (rmbr[0] <= qmbr[2])
        & (qmbr[1] <= rmbr[3])
        & (rmbr[1] <= qmbr[3])
    )


def _tile_hits_any_cover(qmbr, cover):
    """True iff the query-tile MBR (4,) overlaps any cover MBR (K, 4)."""
    hit = (
        (qmbr[0] <= cover[:, 2:3])
        & (cover[:, 0:1] <= qmbr[2])
        & (qmbr[1] <= cover[:, 3:4])
        & (cover[:, 1:2] <= qmbr[3])
    )                                     # (K, 1)
    return jnp.any(hit)


def _phase1_query_mask(q_ref, cover):
    """Per-query Phase-1 filter inside the kernel.

    q_ref (4, TQ) coordinates vs cover (K, 4) MBRs → (TQ,) int32 — the
    paper's "candidate level-1 node" test, evaluated where the data lives.
    """
    qx0 = q_ref[0, :][None, :]            # (1, TQ)
    qy0 = q_ref[1, :][None, :]
    qx1 = q_ref[2, :][None, :]
    qy1 = q_ref[3, :][None, :]
    hit = (
        (cover[:, 0:1] <= qx1)
        & (qx0 <= cover[:, 2:3])
        & (cover[:, 1:2] <= qy1)
        & (qy0 <= cover[:, 3:4])
    )                                     # (K, TQ)
    return jnp.any(hit, axis=0).astype(jnp.int32)


def _pairwise_counts(q_ref, r_ref):
    """(TQ,) int32 overlap counts of one (query-tile, rect-tile) pair."""
    qx0 = q_ref[0, :][:, None]   # (TQ, 1)
    qy0 = q_ref[1, :][:, None]
    qx1 = q_ref[2, :][:, None]
    qy1 = q_ref[3, :][:, None]
    rx0 = r_ref[0, :][None, :]   # (1, TR)
    ry0 = r_ref[1, :][None, :]
    rx1 = r_ref[2, :][None, :]
    ry1 = r_ref[3, :][None, :]
    hits = (qx0 <= rx1) & (rx0 <= qx1) & (qy0 <= ry1) & (ry0 <= qy1)
    return jnp.sum(hits.astype(jnp.int32), axis=1)


def _count_kernel(q_ref, r_ref, qmbr_ref, rmbr_ref, mask_ref, out_ref):
    """One (query-tile, rect-tile) grid step with an explicit Phase-1 mask.

    q_ref    : (4, TQ) int32 VMEM — query coordinates
    r_ref    : (4, TR) int32 VMEM — rect coordinates
    qmbr_ref : (1, 4) int32 — MBR of this query tile
    rmbr_ref : (1, 4) int32 — MBR of this rect tile (leaf-block MBR)
    mask_ref : (1, TQ) int32 — Phase-1 upper-level filter result per query
    out_ref  : (1, TQ) int32 — per-query overlap counts (accumulated over j)
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    prune_ok = _tile_overlap(qmbr_ref[0], rmbr_ref[0])

    @pl.when(prune_ok)
    def _compute():
        cnt = _pairwise_counts(q_ref, r_ref)
        cnt = cnt * (mask_ref[0, :] > 0).astype(jnp.int32)     # Phase-1 gate
        out_ref[0, :] += cnt


@functools.partial(
    jax.jit, static_argnames=("tq", "tr", "interpret")
)
def overlap_counts_tiled(
    q_coords: jnp.ndarray,    # (4, Qp) int32, Qp % tq == 0
    r_coords: jnp.ndarray,    # (4, Rp) int32, Rp % tr == 0
    q_tile_mbrs: jnp.ndarray,  # (Qp // tq, 4) int32
    r_tile_mbrs: jnp.ndarray,  # (Rp // tr, 4) int32
    mask: jnp.ndarray,        # (Qp,) int32 Phase-1 filter
    *,
    tq: int = DEFAULT_TQ,
    tr: int = DEFAULT_TR,
    interpret: bool = False,
) -> jnp.ndarray:
    """Raw tiled kernel call.  Returns (Qp,) int32 counts."""
    qp, rp = q_coords.shape[1], r_coords.shape[1]
    assert qp % tq == 0 and rp % tr == 0, (qp, tq, rp, tr)
    nq, nr = qp // tq, rp // tr
    out = pl.pallas_call(
        _count_kernel,
        grid=(nq, nr),
        in_specs=[
            pl.BlockSpec((4, tq), lambda i, j: (0, i)),
            pl.BlockSpec((4, tr), lambda i, j: (0, j)),
            pl.BlockSpec((1, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 4), lambda i, j: (j, 0)),
            pl.BlockSpec((1, tq), lambda i, j: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, tq), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, qp), jnp.int32),
        interpret=interpret,
    )(q_coords, r_coords, q_tile_mbrs, r_tile_mbrs, mask[None, :])
    return out[0]


def _count_kernel_fused(q_ref, r_ref, qmbr_ref, rmbr_ref, cover_ref, out_ref):
    """Dense grid step with the Phase-1 cover filter fused into the kernel.

    cover_ref : (K, 4) int32 — the device's covering level-1 MBRs (EMPTY
    sentinel padding allowed; sentinels fail every overlap test).  Replaces
    the host-materialized (Q, K) mask of the unfused path.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    cover = cover_ref[...]
    qmbr = qmbr_ref[0]
    prune_ok = _tile_overlap(qmbr, rmbr_ref[0]) & _tile_hits_any_cover(
        qmbr, cover)

    @pl.when(prune_ok)
    def _compute():
        cnt = _pairwise_counts(q_ref, r_ref)
        cnt = cnt * _phase1_query_mask(q_ref, cover)
        out_ref[0, :] += cnt


@functools.partial(
    jax.jit, static_argnames=("tq", "tr", "interpret")
)
def overlap_counts_tiled_fused(
    q_coords: jnp.ndarray,     # (4, Qp) int32, Qp % tq == 0
    r_coords: jnp.ndarray,     # (4, Rp) int32, Rp % tr == 0
    q_tile_mbrs: jnp.ndarray,  # (Qp // tq, 4) int32
    r_tile_mbrs: jnp.ndarray,  # (Rp // tr, 4) int32
    cover_mbrs: jnp.ndarray,   # (K, 4) int32 covering L1 MBRs, EMPTY-padded
    *,
    tq: int = DEFAULT_TQ,
    tr: int = DEFAULT_TR,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused-Phase-1 tiled kernel call.  Returns (Qp,) int32 counts."""
    qp, rp = q_coords.shape[1], r_coords.shape[1]
    assert qp % tq == 0 and rp % tr == 0, (qp, tq, rp, tr)
    nq, nr = qp // tq, rp // tr
    k = cover_mbrs.shape[0]
    out = pl.pallas_call(
        _count_kernel_fused,
        grid=(nq, nr),
        in_specs=[
            pl.BlockSpec((4, tq), lambda i, j: (0, i)),
            pl.BlockSpec((4, tr), lambda i, j: (0, j)),
            pl.BlockSpec((1, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 4), lambda i, j: (j, 0)),
            pl.BlockSpec((k, 4), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, qp), jnp.int32),
        interpret=interpret,
    )(q_coords, r_coords, q_tile_mbrs, r_tile_mbrs, cover_mbrs)
    return out[0]


# ---------------------------------------------------------------------------
# Scalar-prefetch variant: skips DMA of pruned tiles (hillclimb kernel).
# ---------------------------------------------------------------------------


def _sparse_count_kernel(
    nactive_ref, tile_ids_ref,           # scalar-prefetch operands (SMEM)
    q_ref, r_ref, mask_ref, out_ref,
):
    """Grid (nq, max_active): step (i, j) processes the j-th *active* rect
    tile of query tile i.  ``tile_ids[i, j]`` is built from the tile MBRs, so
    dead tiles are never even DMA'd — the faithful analogue of hierarchical
    pruning at DMA granularity."""
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(j < nactive_ref[i])
    def _compute():
        cnt = _pairwise_counts(q_ref, r_ref)
        cnt = cnt * (mask_ref[0, :] > 0).astype(jnp.int32)
        out_ref[0, :] += cnt


@functools.partial(
    jax.jit, static_argnames=("tq", "tr", "interpret")
)
def overlap_counts_sparse(
    q_coords: jnp.ndarray,    # (4, Qp)
    r_coords: jnp.ndarray,    # (4, Rp)
    mask: jnp.ndarray,        # (Qp,)
    nactive: jnp.ndarray,     # (nq,) int32 — active rect tiles per query tile
    tile_ids: jnp.ndarray,    # (nq, max_active) int32 — rect tile indices
    *,
    tq: int = DEFAULT_TQ,
    tr: int = DEFAULT_TR,
    interpret: bool = False,
) -> jnp.ndarray:
    qp, rp = q_coords.shape[1], r_coords.shape[1]
    assert qp % tq == 0 and rp % tr == 0, (qp, tq, rp, tr)
    nq = qp // tq
    max_active = tile_ids.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nq, max_active),
        in_specs=[
            pl.BlockSpec((4, tq), lambda i, j, na, tid: (0, i)),
            pl.BlockSpec((4, tr), lambda i, j, na, tid: (0, tid[i, j])),
            pl.BlockSpec((1, tq), lambda i, j, na, tid: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, tq), lambda i, j, na, tid: (0, i)),
    )
    out = pl.pallas_call(
        _sparse_count_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, qp), jnp.int32),
        interpret=interpret,
    )(nactive, tile_ids, q_coords, r_coords, mask[None, :])
    return out[0]


def _sparse_count_kernel_fused(
    nactive_ref, tile_ids_ref,           # scalar-prefetch operands (SMEM)
    q_ref, r_ref, cover_ref, out_ref,
):
    """Sparse grid step with fused Phase-1: the active-tile list already
    encodes the tile-level cover gate (built on device from cached rect-tile
    MBRs); the per-query cover test runs here against the (K, 4) covers."""
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(j < nactive_ref[i])
    def _compute():
        cnt = _pairwise_counts(q_ref, r_ref)
        cnt = cnt * _phase1_query_mask(q_ref, cover_ref[...])
        out_ref[0, :] += cnt


@functools.partial(
    jax.jit, static_argnames=("tq", "tr", "interpret")
)
def overlap_counts_sparse_fused(
    q_coords: jnp.ndarray,    # (4, Qp)
    r_coords: jnp.ndarray,    # (4, Rp)
    cover_mbrs: jnp.ndarray,  # (K, 4) covering L1 MBRs, EMPTY-padded
    nactive: jnp.ndarray,     # (nq,) int32
    tile_ids: jnp.ndarray,    # (nq, max_active) int32
    *,
    tq: int = DEFAULT_TQ,
    tr: int = DEFAULT_TR,
    interpret: bool = False,
) -> jnp.ndarray:
    qp, rp = q_coords.shape[1], r_coords.shape[1]
    assert qp % tq == 0 and rp % tr == 0, (qp, tq, rp, tr)
    nq = qp // tq
    max_active = tile_ids.shape[1]
    k = cover_mbrs.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nq, max_active),
        in_specs=[
            pl.BlockSpec((4, tq), lambda i, j, na, tid: (0, i)),
            pl.BlockSpec((4, tr), lambda i, j, na, tid: (0, tid[i, j])),
            pl.BlockSpec((k, 4), lambda i, j, na, tid: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq), lambda i, j, na, tid: (0, i)),
    )
    out = pl.pallas_call(
        _sparse_count_kernel_fused,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, qp), jnp.int32),
        interpret=interpret,
    )(nactive, tile_ids, q_coords, r_coords, cover_mbrs)
    return out[0]
