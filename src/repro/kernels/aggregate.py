"""Pallas TPU kernel for on-fabric range-query aggregates (repro.query).

PIMDAL's argument (PAPERS.md) applied to this engine: when the consumer
wants a *statistic* of the matching rects, shipping candidate lists to the
host wastes the fabric — reduce inside the kernel and combine partial
aggregates across devices with ``psum``/``pmin``/``pmax`` instead.  One grid
walk produces, per query:

* ``count``     — int32 match count (exact, same predicate as the count
                  kernels including the fused Phase-1 cover gate);
* ``sums``      — float32 partial sums ``[Σ(x0+x1), Σ(y0+y1), Σ area]``
                  over matching rects.  Downstream: centroid =
                  ``(Σ(x0+x1), Σ(y0+y1)) / (2·count)``, mean area =
                  ``Σ area / count``;
* ``bbox``      — int32 ``[xmin, ymin, xmax, ymax]`` of the matching rects
                  (EMPTY orientation when nothing matches, exactly like the
                  placement-time MBR reductions).

Count and bbox are exact int32.  The float32 sums accumulate in rect-tile
order, which differs from the XLA twin's single-shot reduction and from a
float64 host reference — aggregate results are therefore specified to a
documented tolerance (DESIGN.md Sec 14), not bit-equality.

Grid: ``(num_query_tiles, num_rect_tiles)``, rect axis as reduction axis,
same pruning as the fused count kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.rect_intersect import (
    DEFAULT_TQ, DEFAULT_TR, _phase1_query_mask, _tile_hits_any_cover,
    _tile_overlap)
from repro.kernels.materialize import _pairwise_hits

_INT32_MAX = 2**31 - 1
_INT32_MIN = -(2**31)


def _aggregate_kernel(q_ref, r_ref, qmbr_ref, rmbr_ref, cover_ref,
                      cnt_ref, sum_ref, bbox_ref):
    """One (query-tile, rect-tile) grid step of the aggregate reduction.

    q_ref    : (4, TQ) int32 — query rect coordinates
    r_ref    : (4, TR) int32 — placed rect coordinates
    qmbr_ref : (1, 4) int32 — this query tile's MBR
    rmbr_ref : (1, 4) int32 — this rect tile's MBR
    cover_ref: (K, 4) int32 — covering L1 MBRs (fused Phase-1)
    cnt_ref  : (1, TQ) i32 out — match counts
    sum_ref  : (3, TQ) f32 out — [Σ(x0+x1), Σ(y0+y1), Σ area]
    bbox_ref : (4, TQ) i32 out — match bbox, EMPTY orientation when empty
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        sum_ref[...] = jnp.zeros_like(sum_ref)
        tq = bbox_ref.shape[1]
        bbox_ref[...] = jnp.concatenate([
            jnp.full((2, tq), _INT32_MAX, jnp.int32),
            jnp.full((2, tq), _INT32_MIN, jnp.int32),
        ], axis=0)

    cover = cover_ref[...]
    qmbr = qmbr_ref[0]
    prune_ok = _tile_overlap(qmbr, rmbr_ref[0]) & _tile_hits_any_cover(
        qmbr, cover)

    @pl.when(prune_ok)
    def _compute():
        hit = _pairwise_hits(q_ref, r_ref)
        hit = hit & (_phase1_query_mask(q_ref, cover) > 0)[:, None]
        rx0 = r_ref[0, :][None, :].astype(jnp.float32)
        ry0 = r_ref[1, :][None, :].astype(jnp.float32)
        rx1 = r_ref[2, :][None, :].astype(jnp.float32)
        ry1 = r_ref[3, :][None, :].astype(jnp.float32)
        zero = jnp.float32(0.0)
        sum_cx = jnp.sum(jnp.where(hit, rx0 + rx1, zero), axis=1)
        sum_cy = jnp.sum(jnp.where(hit, ry0 + ry1, zero), axis=1)
        area = (rx1 - rx0) * (ry1 - ry0)
        sum_area = jnp.sum(jnp.where(hit, area, zero), axis=1)
        cnt_ref[0, :] += jnp.sum(hit.astype(jnp.int32), axis=1)
        sum_ref[...] += jnp.stack([sum_cx, sum_cy, sum_area], axis=0)
        ri = r_ref[...]
        xmin = jnp.min(jnp.where(hit, ri[0, :][None, :], _INT32_MAX), axis=1)
        ymin = jnp.min(jnp.where(hit, ri[1, :][None, :], _INT32_MAX), axis=1)
        xmax = jnp.max(jnp.where(hit, ri[2, :][None, :], _INT32_MIN), axis=1)
        ymax = jnp.max(jnp.where(hit, ri[3, :][None, :], _INT32_MIN), axis=1)
        bbox_ref[...] = jnp.stack([
            jnp.minimum(bbox_ref[0, :], xmin),
            jnp.minimum(bbox_ref[1, :], ymin),
            jnp.maximum(bbox_ref[2, :], xmax),
            jnp.maximum(bbox_ref[3, :], ymax),
        ], axis=0)


@functools.partial(
    jax.jit, static_argnames=("tq", "tr", "interpret")
)
def aggregate_tiled(
    q_coords: jnp.ndarray,     # (4, Qp) int32, Qp % tq == 0
    r_coords: jnp.ndarray,     # (4, Rp) int32, Rp % tr == 0
    q_tile_mbrs: jnp.ndarray,  # (Qp // tq, 4) int32
    r_tile_mbrs: jnp.ndarray,  # (Rp // tr, 4) int32
    cover_mbrs: jnp.ndarray,   # (K, 4) int32, EMPTY-padded
    *,
    tq: int = DEFAULT_TQ,
    tr: int = DEFAULT_TR,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """On-fabric aggregates per query.

    Returns ``(counts (Qp,) i32, sums (3, Qp) f32, bbox (4, Qp) i32)`` —
    per-device partials, combined across devices with psum (counts, sums)
    and pmin/pmax (bbox) by the query pipeline.
    """
    qp, rp = q_coords.shape[1], r_coords.shape[1]
    assert qp % tq == 0 and rp % tr == 0, (qp, tq, rp, tr)
    nq, nr = qp // tq, rp // tr
    k = cover_mbrs.shape[0]
    counts, sums, bbox = pl.pallas_call(
        _aggregate_kernel,
        grid=(nq, nr),
        in_specs=[
            pl.BlockSpec((4, tq), lambda i, j: (0, i)),
            pl.BlockSpec((4, tr), lambda i, j: (0, j)),
            pl.BlockSpec((1, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 4), lambda i, j: (j, 0)),
            pl.BlockSpec((k, 4), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, tq), lambda i, j: (0, i)),
            pl.BlockSpec((3, tq), lambda i, j: (0, i)),
            pl.BlockSpec((4, tq), lambda i, j: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, qp), jnp.int32),
            jax.ShapeDtypeStruct((3, qp), jnp.float32),
            jax.ShapeDtypeStruct((4, qp), jnp.int32),
        ],
        interpret=interpret,
    )(q_coords, r_coords, q_tile_mbrs, r_tile_mbrs, cover_mbrs)
    return counts[0], sums, bbox
