"""Pure-jnp oracles for the rectangle-intersection kernels.

These are the ground-truth implementations every Pallas kernel and every
engine is validated against (``assert_allclose`` / exact int equality in the
tests).  They are deliberately simple: broadcasted closed-interval overlap
tests, chunked over queries to bound memory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rect_overlap(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Closed-interval overlap between broadcastable (..., 4) rect arrays."""
    return (
        (a[..., 0] <= b[..., 2])
        & (b[..., 0] <= a[..., 2])
        & (a[..., 1] <= b[..., 3])
        & (b[..., 1] <= a[..., 3])
    )


def overlap_counts_ref(
    queries: jnp.ndarray, rects: jnp.ndarray, query_chunk: int | None = None
) -> jnp.ndarray:
    """Per-query count of overlapping rects.  queries (Q,4), rects (R,4) →
    (Q,) int32.  Padding rects must use the EMPTY sentinel (xmin > xmax).

    ``query_chunk`` defaults to ``min(1024, Q)`` — a fixed 1024 chunk pads a
    small batch up to 4× its size in wasted pair tests (the pre-cache engine
    did exactly that on every serving batch)."""
    q = queries.shape[0]
    if query_chunk is None:
        query_chunk = min(1024, max(q, 1))
    pad = (-q) % query_chunk
    qp = jnp.pad(queries, ((0, pad), (0, 0)))

    def body(carry, qc):
        hits = rect_overlap(qc[:, None, :], rects[None, :, :])
        return carry, hits.sum(axis=1, dtype=jnp.int32)

    _, out = jax.lax.scan(
        body, None, qp.reshape(-1, query_chunk, 4)
    )
    return out.reshape(-1)[:q]


def overlap_counts_np(queries: np.ndarray, rects: np.ndarray) -> np.ndarray:
    """Numpy oracle (host-side, used by hypothesis tests)."""
    out = np.zeros(queries.shape[0], dtype=np.int32)
    for i, qr in enumerate(queries):
        hit = (
            (qr[0] <= rects[:, 2])
            & (rects[:, 0] <= qr[2])
            & (qr[1] <= rects[:, 3])
            & (rects[:, 1] <= qr[3])
        )
        out[i] = hit.sum()
    return out


def overlap_counts_np_chunked(
    queries: np.ndarray, rects: np.ndarray, chunk: int = 256
) -> np.ndarray:
    """Vectorized NumPy twin of :func:`overlap_counts_np`, chunked over
    queries to bound the (chunk, R) broadcast.

    This is the serving layer's graceful-degradation path
    (``repro.serve.spatial_serve``): when the device fast path is lost, a
    batch must still be answered exactly from the host copy of the leaf
    rects, and the per-query Python loop of ``overlap_counts_np`` is too
    slow for whole serving batches."""
    q = queries.shape[0]
    out = np.zeros(q, dtype=np.int32)
    for lo in range(0, q, chunk):
        qc = queries[lo: lo + chunk]
        hits = (
            (qc[:, None, 0] <= rects[None, :, 2])
            & (rects[None, :, 0] <= qc[:, None, 2])
            & (qc[:, None, 1] <= rects[None, :, 3])
            & (rects[None, :, 1] <= qc[:, None, 3])
        )
        out[lo: lo + chunk] = hits.sum(axis=1, dtype=np.int32)
    return out


def masked_overlap_counts_ref(
    queries: jnp.ndarray, mask: jnp.ndarray, rects: jnp.ndarray,
    query_chunk: int | None = None,
) -> jnp.ndarray:
    """Two-phase reference: Phase-1 mask (Q,) bool gates the Phase-2 leaf
    scan, mirroring Algorithm 3 on a single shard."""
    counts = overlap_counts_ref(queries, rects, query_chunk=query_chunk)
    return jnp.where(mask, counts, 0).astype(jnp.int32)
