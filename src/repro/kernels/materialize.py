"""Pallas TPU kernels for result-ID materialization (repro.query pass 2).

The count kernels (:mod:`repro.kernels.rect_intersect`) answer "how many
rects match"; these kernels answer "*which* rects match" without ever
shipping a ``(Q, R)`` candidate mask to the host (pallint PL113).  The
two-pass dataflow (DESIGN.md Sec 14):

pass 1   the existing fused count kernel → per-device per-query counts;
offsets  an exclusive prefix over the per-device counts (computed in the
         shard_map body from an on-fabric gather) gives each device the
         *global* slot range its matches occupy for every query;
pass 2   the scatter kernels below walk the same (query-tile × rect-tile)
         grid and write each match's source ID into a fixed-shape
         ``(Q, Kcap)`` slot buffer at ``base + running_local_rank``.

Slot encoding: IDs are written *plus one* into a zero-initialized buffer, so
a cross-device ``psum`` merges the disjoint per-device slot writes (zeros
elsewhere are the identity); the pipeline subtracts 1 afterwards, leaving
``-1`` in empty slots.  Matches are therefore returned in ascending placed
order — deterministic and device-count-invariant for a fixed layout.

Overflow: a match whose global slot is ``>= Kcap`` is dropped at the write
(saturation); the per-query total from pass 1 still counts it, so the
pipeline reports ``overflow = max(total - Kcap, 0)`` per query.

The radius variant replaces the rect-overlap predicate with a squared
point-to-rect distance test (closed ball, float32 — see
:func:`repro.kernels.knn.point_rect_dist2` for the exactness argument).

Grid: ``(num_query_tiles, num_rect_tiles)`` with the rect axis as the
reduction axis, like the count kernels; the running per-query hit count is
carried in the counts output block between rect-tile steps.  Default tiles
are smaller than the count kernels' because the scatter builds a
(TQ, TR, Kcap) one-hot intermediate in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.rect_intersect import (
    _pairwise_counts, _phase1_query_mask, _tile_hits_any_cover, _tile_overlap)
from repro.kernels.knn import (
    _pairwise_dist2, _tile_min_dist2, _PRUNE_MARGIN)

# (TQ, TR, Kcap) int32 one-hot working set: 128 * 256 * 64 * 4 B = 8 MB.
DEFAULT_TQ = 128
DEFAULT_TR = 256
DEFAULT_KCAP = 64


def _pairwise_hits(q_ref, r_ref):
    """(TQ, TR) bool overlap matrix of one (query-tile, rect-tile) pair."""
    qx0 = q_ref[0, :][:, None]
    qy0 = q_ref[1, :][:, None]
    qx1 = q_ref[2, :][:, None]
    qy1 = q_ref[3, :][:, None]
    rx0 = r_ref[0, :][None, :]
    ry0 = r_ref[1, :][None, :]
    rx1 = r_ref[2, :][None, :]
    ry1 = r_ref[3, :][None, :]
    return (qx0 <= rx1) & (rx0 <= qx1) & (qy0 <= ry1) & (ry0 <= qy1)


def _scatter_tile(hit, ids_plus1, pos, kcap):
    """Scatter one tile's matches into their (TQ, Kcap) slot contribution.

    hit       : (TQ, TR) bool — matches in this tile
    ids_plus1 : (1, TR) int32 — source IDs + 1 (0 is the empty sentinel)
    pos       : (TQ, TR) int32 — global slot index of each match
    Writes saturate at ``kcap``: slots beyond the cap are dropped here and
    surface as per-query overflow in the pipeline.
    """
    tq = hit.shape[0]
    write = hit & (pos >= 0) & (pos < kcap)
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (tq, kcap), 1)
    onehot = (pos[:, :, None] == iota_k[:, None, :]) & write[:, :, None]
    return jnp.sum(
        onehot.astype(jnp.int32) * ids_plus1[0, :][None, :, None], axis=1)


def _scatter_ids_kernel(q_ref, r_ref, id_ref, qmbr_ref, rmbr_ref, cover_ref,
                        base_ref, slot_ref, cnt_ref):
    """Range-query ID scatter grid step.

    q_ref    : (4, TQ) int32 — query rect coordinates
    r_ref    : (4, TR) int32 — placed rect coordinates
    id_ref   : (1, TR) int32 — source IDs of the placed rects (-1 padding)
    qmbr_ref : (1, 4) int32 — this query tile's MBR
    rmbr_ref : (1, 4) int32 — this rect tile's MBR (placement-time cache)
    cover_ref: (K, 4) int32 — covering L1 MBRs (fused Phase-1)
    base_ref : (1, TQ) int32 — per-query global slot offset of this device
    slot_ref : (TQ, Kcap) int32 out — IDs + 1, 0 = empty (psum-mergeable)
    cnt_ref  : (1, TQ) int32 out — running local match count (the carry)
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        slot_ref[...] = jnp.zeros_like(slot_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    cover = cover_ref[...]
    qmbr = qmbr_ref[0]
    prune_ok = _tile_overlap(qmbr, rmbr_ref[0]) & _tile_hits_any_cover(
        qmbr, cover)

    @pl.when(prune_ok)
    def _compute():
        kcap = slot_ref.shape[1]
        hit = _pairwise_hits(q_ref, r_ref)
        hit = hit & (_phase1_query_mask(q_ref, cover) > 0)[:, None]
        prior = cnt_ref[0, :]
        excl = jnp.cumsum(hit.astype(jnp.int32), axis=1) - hit.astype(
            jnp.int32)
        pos = base_ref[0, :][:, None] + prior[:, None] + excl
        slot_ref[...] += _scatter_tile(hit, id_ref[...] + 1, pos, kcap)
        cnt_ref[0, :] += jnp.sum(hit.astype(jnp.int32), axis=1)


@functools.partial(
    jax.jit, static_argnames=("kcap", "tq", "tr", "interpret")
)
def materialize_ids_tiled(
    q_coords: jnp.ndarray,     # (4, Qp) int32, Qp % tq == 0
    r_coords: jnp.ndarray,     # (4, Rp) int32, Rp % tr == 0
    r_ids: jnp.ndarray,        # (Rp,) int32 source IDs, -1 padding
    q_tile_mbrs: jnp.ndarray,  # (Qp // tq, 4) int32
    r_tile_mbrs: jnp.ndarray,  # (Rp // tr, 4) int32
    cover_mbrs: jnp.ndarray,   # (K, 4) int32, EMPTY-padded
    base: jnp.ndarray,         # (Qp,) int32 per-query global slot offsets
    *,
    kcap: int = DEFAULT_KCAP,
    tq: int = DEFAULT_TQ,
    tr: int = DEFAULT_TR,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Pass-2 ID scatter.  Returns ``(slots_plus1 (Qp, kcap), counts (Qp,))``.

    ``slots_plus1`` holds source IDs + 1 at their global slots (0 = empty)
    so the pipeline can psum-merge devices before subtracting 1.
    """
    qp, rp = q_coords.shape[1], r_coords.shape[1]
    assert qp % tq == 0 and rp % tr == 0, (qp, tq, rp, tr)
    nq, nr = qp // tq, rp // tr
    k = cover_mbrs.shape[0]
    slots, counts = pl.pallas_call(
        _scatter_ids_kernel,
        grid=(nq, nr),
        in_specs=[
            pl.BlockSpec((4, tq), lambda i, j: (0, i)),
            pl.BlockSpec((4, tr), lambda i, j: (0, j)),
            pl.BlockSpec((1, tr), lambda i, j: (0, j)),
            pl.BlockSpec((1, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 4), lambda i, j: (j, 0)),
            pl.BlockSpec((k, 4), lambda i, j: (0, 0)),
            pl.BlockSpec((1, tq), lambda i, j: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((tq, kcap), lambda i, j: (i, 0)),
            pl.BlockSpec((1, tq), lambda i, j: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qp, kcap), jnp.int32),
            jax.ShapeDtypeStruct((1, qp), jnp.int32),
        ],
        interpret=interpret,
    )(q_coords, r_coords, r_ids[None, :], q_tile_mbrs, r_tile_mbrs,
      cover_mbrs, base[None, :])
    return slots, counts[0]


def _scatter_radius_kernel(p_ref, rad_ref, r_ref, id_ref, qmbr_ref, rmbr_ref,
                           base_ref, slot_ref, cnt_ref):
    """Radius-query ID scatter grid step (closed ball, squared f32 metric).

    p_ref    : (2, TQ) int32 — query point coordinates
    rad_ref  : (1, TQ) int32 — per-query radii (< 0 marks padding slots)
    qmbr_ref : (1, 4) int32 — bbox of this point tile
    Other refs as in :func:`_scatter_ids_kernel`; no cover operand — the
    L1 covers encode the *overlap* filter, which does not bound distance.
    Tile pruning compares the tile min-distance against the tile's largest
    radius with the conservative f32 margin from :mod:`repro.kernels.knn`.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        slot_ref[...] = jnp.zeros_like(slot_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    rmbr = rmbr_ref[0]
    rad = rad_ref[0, :]
    maxr = jnp.max(rad)
    maxr2 = maxr.astype(jnp.float32) * maxr.astype(jnp.float32)
    mind2 = _tile_min_dist2(qmbr_ref[0], rmbr)
    tile_valid = rmbr[0] <= rmbr[2]
    prune_ok = tile_valid & (maxr >= 0) & (mind2 * _PRUNE_MARGIN <= maxr2)

    @pl.when(prune_ok)
    def _compute():
        kcap = slot_ref.shape[1]
        d2, valid = _pairwise_dist2(p_ref, r_ref)
        r2 = rad.astype(jnp.float32) * rad.astype(jnp.float32)
        hit = valid & (rad >= 0)[:, None] & (d2 <= r2[:, None])
        prior = cnt_ref[0, :]
        excl = jnp.cumsum(hit.astype(jnp.int32), axis=1) - hit.astype(
            jnp.int32)
        pos = base_ref[0, :][:, None] + prior[:, None] + excl
        slot_ref[...] += _scatter_tile(hit, id_ref[...] + 1, pos, kcap)
        cnt_ref[0, :] += jnp.sum(hit.astype(jnp.int32), axis=1)


@functools.partial(
    jax.jit, static_argnames=("kcap", "tq", "tr", "interpret")
)
def materialize_radius_tiled(
    p_coords: jnp.ndarray,     # (2, Qp) int32 point coordinates
    radii: jnp.ndarray,        # (Qp,) int32, < 0 marks padding
    r_coords: jnp.ndarray,     # (4, Rp) int32
    r_ids: jnp.ndarray,        # (Rp,) int32 source IDs
    q_tile_mbrs: jnp.ndarray,  # (Qp // tq, 4) int32 point-tile bboxes
    r_tile_mbrs: jnp.ndarray,  # (Rp // tr, 4) int32
    base: jnp.ndarray,         # (Qp,) int32 global slot offsets
    *,
    kcap: int = DEFAULT_KCAP,
    tq: int = DEFAULT_TQ,
    tr: int = DEFAULT_TR,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Radius-query pass-2 scatter.  Same contract as
    :func:`materialize_ids_tiled` with the ball predicate."""
    qp, rp = p_coords.shape[1], r_coords.shape[1]
    assert qp % tq == 0 and rp % tr == 0, (qp, tq, rp, tr)
    nq, nr = qp // tq, rp // tr
    slots, counts = pl.pallas_call(
        _scatter_radius_kernel,
        grid=(nq, nr),
        in_specs=[
            pl.BlockSpec((2, tq), lambda i, j: (0, i)),
            pl.BlockSpec((1, tq), lambda i, j: (0, i)),
            pl.BlockSpec((4, tr), lambda i, j: (0, j)),
            pl.BlockSpec((1, tr), lambda i, j: (0, j)),
            pl.BlockSpec((1, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 4), lambda i, j: (j, 0)),
            pl.BlockSpec((1, tq), lambda i, j: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((tq, kcap), lambda i, j: (i, 0)),
            pl.BlockSpec((1, tq), lambda i, j: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qp, kcap), jnp.int32),
            jax.ShapeDtypeStruct((1, qp), jnp.int32),
        ],
        interpret=interpret,
    )(p_coords, radii[None, :], r_coords, r_ids[None, :], q_tile_mbrs,
      r_tile_mbrs, base[None, :])
    return slots, counts[0]
