"""Jitted public wrappers around the rectangle-intersection kernels.

``overlap_counts(queries, rects, mask)`` is the generic op.  Three execution
paths, selected by ``impl=``:

* ``"pallas"``  — the Pallas TPU kernel (interpret=True on CPU containers).
* ``"sparse"``  — the scalar-prefetch Pallas kernel; active tile lists are
                  built *on device* with a single argsort/cumsum construction
                  (DMA-level pruning; §Perf hillclimb kernel).
* ``"xla"``     — pure-jnp tiled equivalent (same math, XLA codegen).  This
                  is the fast path on CPU and the cross-check on TPU.

Any other ``impl`` raises ``ValueError`` — historically ``"sparse"`` fell
through to the dense Pallas path silently.

``overlap_counts_fused(queries, r_coords, r_tile_mbrs, cover_mbrs)`` is the
engine-facing op for the device-resident pipeline (DESIGN.md Sec 3/4): the
rect-side metadata (transposed coordinates + per-tile MBRs) is computed once
at placement time and lives on device; only query-side metadata (tile MBRs of
the current batch) is derived per batch, on device, inside the jitted step.
The Phase-1 cover filter is fused into the kernels instead of materializing a
(Q, Kmax) boolean mask per batch.

All paths are exact-int equal to :mod:`repro.kernels.ref`.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import rect_intersect as rk
from repro.kernels import ref

INT32_MAX = 2**31 - 1
INT32_MIN = -(2**31)

IMPLS = ("pallas", "sparse", "xla")

# On CPU containers the Pallas kernel runs in interpret mode (the kernel body
# executes in Python) — correct but slow, so engines default to the XLA path
# unless REPRO_KERNEL_IMPL overrides it.
DEFAULT_IMPL = os.environ.get(
    "REPRO_KERNEL_IMPL",
    "xla" if jax.default_backend() == "cpu" else "pallas",
)
_INTERPRET = jax.default_backend() == "cpu"


def pad_rects_to(rects: jnp.ndarray, multiple: int) -> jnp.ndarray:
    """Pad an (N, 4) rect array with EMPTY sentinels to a multiple."""
    n = rects.shape[0]
    pad = (-n) % multiple
    if pad == 0:
        return rects
    empty = jnp.array([INT32_MAX, INT32_MAX, INT32_MIN, INT32_MIN],
                      dtype=rects.dtype)
    return jnp.concatenate([rects, jnp.tile(empty, (pad, 1))], axis=0)


def pad_rects_to_np(rects: np.ndarray, multiple: int) -> np.ndarray:
    """Host twin of :func:`pad_rects_to` — pure NumPy, no device bounce."""
    n = rects.shape[0]
    pad = (-n) % multiple
    if pad == 0:
        return rects
    empty = np.array([INT32_MAX, INT32_MAX, INT32_MIN, INT32_MIN],
                     dtype=rects.dtype)
    return np.concatenate([rects, np.tile(empty, (pad, 1))], axis=0)


def tile_mbrs_np(rects: np.ndarray, tile: int) -> np.ndarray:
    """Host twin of :func:`tile_mbrs` — pure NumPy, no device bounce."""
    r = rects.reshape(-1, tile, 4)
    return np.concatenate(
        [r[..., :2].min(axis=1), r[..., 2:].max(axis=1)], axis=-1
    )


def tile_mbrs(rects: jnp.ndarray, tile: int) -> jnp.ndarray:
    """Per-tile MBRs of an (Np, 4) rect array, Np % tile == 0 → (Np/tile, 4).

    Sentinel-safe: empty slots contribute INT32_MAX minima / INT32_MIN maxima
    and so never widen a tile MBR; an all-empty tile gets the EMPTY MBR and is
    pruned everywhere."""
    r = rects.reshape(-1, tile, 4)
    return jnp.concatenate(
        [r[..., :2].min(axis=1), r[..., 2:].max(axis=1)], axis=-1
    )


def _xla_counts(queries, rects, mask, tq, tr):
    del tq, tr
    return ref.masked_overlap_counts_ref(queries, mask, rects)


@functools.partial(
    jax.jit, static_argnames=("tq", "tr", "impl")
)
def overlap_counts(
    queries: jnp.ndarray,     # (Q, 4) int32
    rects: jnp.ndarray,       # (R, 4) int32 (EMPTY-padded slots allowed)
    mask: jnp.ndarray | None = None,   # (Q,) bool/int Phase-1 filter
    *,
    tq: int = rk.DEFAULT_TQ,
    tr: int = rk.DEFAULT_TR,
    impl: str = DEFAULT_IMPL,
) -> jnp.ndarray:
    """Per-query overlap counts with optional Phase-1 gating.  (Q,) int32."""
    if impl not in IMPLS:
        raise ValueError(f"unknown impl {impl!r}; expected one of {IMPLS}")
    q = queries.shape[0]
    if q == 0:        # empty batch: a zero-extent grid has no tile to load
        return jnp.zeros((0,), jnp.int32)
    if mask is None:
        mask = jnp.ones((q,), jnp.int32)
    mask = mask.astype(jnp.int32)

    if impl == "xla":
        with jax.named_scope("overlap_counts_xla"):
            return _xla_counts(queries, rects, mask, tq, tr)

    qp = pad_rects_to(queries, tq)
    rp = pad_rects_to(rects, tr)
    maskp = jnp.pad(mask, (0, qp.shape[0] - q))
    q_coords = qp.T                       # (4, Qp)
    r_coords = rp.T                       # (4, Rp)
    qmbrs = tile_mbrs(qp, tq)
    rmbrs = tile_mbrs(rp, tr)
    if impl == "sparse":
        with jax.named_scope("overlap_counts_sparse"):
            nactive, tile_ids = build_active_tiles_device(qmbrs, rmbrs)
            out = rk.overlap_counts_sparse(
                q_coords, r_coords, maskp, nactive, tile_ids,
                tq=tq, tr=tr, interpret=_INTERPRET,
            )
    else:
        with jax.named_scope("overlap_counts_tiled"):
            out = rk.overlap_counts_tiled(
                q_coords, r_coords, qmbrs, rmbrs, maskp,
                tq=tq, tr=tr, interpret=_INTERPRET,
            )
    return out[:q]


@functools.partial(
    jax.jit, static_argnames=("tq", "tr", "impl")
)
def overlap_counts_fused(
    queries: jnp.ndarray,       # (Q, 4) int32 query batch
    r_coords: jnp.ndarray,      # (4, Rp) int32 — placement-time transpose
    r_tile_mbrs: jnp.ndarray,   # (Rp // tr, 4) int32 — placement-time MBRs
    cover_mbrs: jnp.ndarray,    # (K, 4) int32 covering L1 MBRs, EMPTY-padded
    *,
    tq: int = rk.DEFAULT_TQ,
    tr: int = rk.DEFAULT_TR,
    impl: str = DEFAULT_IMPL,
) -> jnp.ndarray:
    """Device-resident two-phase counts.  (Q,) int32.

    The rect side arrives pre-tiled (coords transposed, tile MBRs cached at
    placement); only the query side is tiled here, on device.  Phase-1 runs
    fused inside the kernel against ``cover_mbrs``.
    """
    if impl not in IMPLS:
        raise ValueError(f"unknown impl {impl!r}; expected one of {IMPLS}")
    q = queries.shape[0]
    if q == 0:        # empty batch: a zero-extent grid has no tile to load
        return jnp.zeros((0,), jnp.int32)
    if impl == "xla":
        with jax.named_scope("overlap_counts_fused_xla"):
            mask = ref.rect_overlap(
                queries[:, None, :], cover_mbrs[None, :, :]).any(axis=1)
            return ref.masked_overlap_counts_ref(queries, mask, r_coords.T)

    qp = pad_rects_to(queries, tq)
    q_coords = qp.T
    qmbrs = tile_mbrs(qp, tq)
    if impl == "sparse":
        with jax.named_scope("overlap_counts_fused_sparse"):
            nactive, tile_ids = build_active_tiles_device(
                qmbrs, r_tile_mbrs, cover_mbrs)
            out = rk.overlap_counts_sparse_fused(
                q_coords, r_coords, cover_mbrs, nactive, tile_ids,
                tq=tq, tr=tr, interpret=_INTERPRET,
            )
    else:
        with jax.named_scope("overlap_counts_fused_tiled"):
            out = rk.overlap_counts_tiled_fused(
                q_coords, r_coords, qmbrs, r_tile_mbrs, cover_mbrs,
                tq=tq, tr=tr, interpret=_INTERPRET,
            )
    return out[:q]


def _active_matrix_np(q_tile_mbrs: np.ndarray,
                      r_tile_mbrs: np.ndarray) -> np.ndarray:
    return (
        (q_tile_mbrs[:, None, 0] <= r_tile_mbrs[None, :, 2])
        & (r_tile_mbrs[None, :, 0] <= q_tile_mbrs[:, None, 2])
        & (q_tile_mbrs[:, None, 1] <= r_tile_mbrs[None, :, 3])
        & (r_tile_mbrs[None, :, 1] <= q_tile_mbrs[:, None, 3])
    )


def build_active_tiles(
    q_tile_mbrs: np.ndarray, r_tile_mbrs: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Host-side construction of the scalar-prefetch active-tile lists.

    For each query tile, the list of rect tiles whose MBRs overlap it,
    left-packed by a single stable argsort (active columns sort before dead
    ones; stability preserves ascending tile order).  Dead entries point at
    tile 0 and are masked by ``nactive``.
    """
    qo = _active_matrix_np(q_tile_mbrs, r_tile_mbrs)
    nactive = qo.sum(axis=1).astype(np.int32)
    max_active = max(int(nactive.max()), 1)
    order = np.argsort(~qo, axis=1, kind="stable")[:, :max_active]
    keep = np.arange(max_active)[None, :] < nactive[:, None]
    tile_ids = np.where(keep, order, 0).astype(np.int32)
    return nactive, tile_ids


def build_active_tiles_device(
    q_tile_mbrs: jnp.ndarray,
    r_tile_mbrs: jnp.ndarray,
    cover_mbrs: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Device-side active-tile lists (trace-safe jnp twin of
    :func:`build_active_tiles`).

    The list width is the static worst case (all rect tiles active); dead
    entries are skipped by the kernel's ``j < nactive`` guard.  When
    ``cover_mbrs`` is given, query tiles missing every cover MBR get an empty
    list — the tile-level half of the fused Phase-1 filter.
    """
    qo = ref.rect_overlap(
        q_tile_mbrs[:, None, :], r_tile_mbrs[None, :, :])     # (nq, nr)
    if cover_mbrs is not None:
        qcov = ref.rect_overlap(
            q_tile_mbrs[:, None, :], cover_mbrs[None, :, :]).any(axis=1)
        qo = qo & qcov[:, None]
    nq, nr = qo.shape
    nactive = qo.sum(axis=1, dtype=jnp.int32)
    order = jnp.argsort(
        jnp.logical_not(qo).astype(jnp.int32), axis=1, stable=True
    ).astype(jnp.int32)
    keep = jax.lax.broadcasted_iota(jnp.int32, (nq, nr), 1) < nactive[:, None]
    tile_ids = jnp.where(keep, order, 0)
    return nactive, tile_ids


def overlap_counts_sparse_host(
    queries: np.ndarray,
    rects: np.ndarray,
    mask: np.ndarray | None = None,
    *,
    tq: int = rk.DEFAULT_TQ,
    tr: int = rk.DEFAULT_TR,
) -> jnp.ndarray:
    """Sparse (scalar-prefetch) path; tile lists built on host from MBRs.

    Kept as the pre-cache reference pipeline: every call re-derives all tile
    metadata on the host — exactly the per-batch cost the device-resident
    engine amortizes away (measured in benchmarks/regress.py).  The metadata
    is built in pure NumPy and crosses to the device exactly once; the old
    ``np.asarray(pad_rects_to(jnp.asarray(...)))`` host→device→host bounce
    was pallint PL108's first catch.
    """
    q = queries.shape[0]
    if mask is None:
        mask = np.ones((q,), np.int32)
    qp = pad_rects_to_np(np.asarray(queries, np.int32), tq)
    rp = pad_rects_to_np(np.asarray(rects, np.int32), tr)
    maskp = np.pad(np.asarray(mask, np.int32), (0, qp.shape[0] - q))
    qmbrs = tile_mbrs_np(qp, tq)
    rmbrs = tile_mbrs_np(rp, tr)
    nactive, tile_ids = build_active_tiles(qmbrs, rmbrs)
    out = rk.overlap_counts_sparse(
        jnp.asarray(qp.T), jnp.asarray(rp.T), jnp.asarray(maskp),
        jnp.asarray(nactive), jnp.asarray(tile_ids),
        tq=tq, tr=tr, interpret=_INTERPRET,
    )
    return out[:q]
