"""Jitted public wrappers around the rectangle-intersection kernels.

``overlap_counts(queries, rects, mask)`` is the generic op.  Three execution
paths, selected by ``impl=``:

* ``"pallas"``  — the Pallas TPU kernel (interpret=True on CPU containers).
* ``"sparse"``  — the scalar-prefetch Pallas kernel; active tile lists are
                  built *on device* with a single argsort/cumsum construction
                  (DMA-level pruning; §Perf hillclimb kernel).
* ``"xla"``     — pure-jnp tiled equivalent (same math, XLA codegen).  This
                  is the fast path on CPU and the cross-check on TPU.

Any other ``impl`` raises ``ValueError`` — historically ``"sparse"`` fell
through to the dense Pallas path silently.

``overlap_counts_fused(queries, r_coords, r_tile_mbrs, cover_mbrs)`` is the
engine-facing op for the device-resident pipeline (DESIGN.md Sec 3/4): the
rect-side metadata (transposed coordinates + per-tile MBRs) is computed once
at placement time and lives on device; only query-side metadata (tile MBRs of
the current batch) is derived per batch, on device, inside the jitted step.
The Phase-1 cover filter is fused into the kernels instead of materializing a
(Q, Kmax) boolean mask per batch.

All paths are exact-int equal to :mod:`repro.kernels.ref`.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import aggregate as ka
from repro.kernels import knn as kk
from repro.kernels import materialize as km
from repro.kernels import rect_intersect as rk
from repro.kernels import ref

INT32_MAX = 2**31 - 1
INT32_MIN = -(2**31)

IMPLS = ("pallas", "sparse", "xla")

# On CPU containers the Pallas kernel runs in interpret mode (the kernel body
# executes in Python) — correct but slow, so engines default to the XLA path
# unless REPRO_KERNEL_IMPL overrides it.
DEFAULT_IMPL = os.environ.get(
    "REPRO_KERNEL_IMPL",
    "xla" if jax.default_backend() == "cpu" else "pallas",
)
_INTERPRET = jax.default_backend() == "cpu"


def pad_rects_to(rects: jnp.ndarray, multiple: int) -> jnp.ndarray:
    """Pad an (N, 4) rect array with EMPTY sentinels to a multiple."""
    n = rects.shape[0]
    pad = (-n) % multiple
    if pad == 0:
        return rects
    empty = jnp.array([INT32_MAX, INT32_MAX, INT32_MIN, INT32_MIN],
                      dtype=rects.dtype)
    return jnp.concatenate([rects, jnp.tile(empty, (pad, 1))], axis=0)


def pad_rects_to_np(rects: np.ndarray, multiple: int) -> np.ndarray:
    """Host twin of :func:`pad_rects_to` — pure NumPy, no device bounce."""
    n = rects.shape[0]
    pad = (-n) % multiple
    if pad == 0:
        return rects
    empty = np.array([INT32_MAX, INT32_MAX, INT32_MIN, INT32_MIN],
                     dtype=rects.dtype)
    return np.concatenate([rects, np.tile(empty, (pad, 1))], axis=0)


def tile_mbrs_np(rects: np.ndarray, tile: int) -> np.ndarray:
    """Host twin of :func:`tile_mbrs` — pure NumPy, no device bounce."""
    r = rects.reshape(-1, tile, 4)
    return np.concatenate(
        [r[..., :2].min(axis=1), r[..., 2:].max(axis=1)], axis=-1
    )


def tile_mbrs(rects: jnp.ndarray, tile: int) -> jnp.ndarray:
    """Per-tile MBRs of an (Np, 4) rect array, Np % tile == 0 → (Np/tile, 4).

    Sentinel-safe: empty slots contribute INT32_MAX minima / INT32_MIN maxima
    and so never widen a tile MBR; an all-empty tile gets the EMPTY MBR and is
    pruned everywhere."""
    r = rects.reshape(-1, tile, 4)
    return jnp.concatenate(
        [r[..., :2].min(axis=1), r[..., 2:].max(axis=1)], axis=-1
    )


def _xla_counts(queries, rects, mask, tq, tr):
    del tq, tr
    return ref.masked_overlap_counts_ref(queries, mask, rects)


@functools.partial(
    jax.jit, static_argnames=("tq", "tr", "impl")
)
def overlap_counts(
    queries: jnp.ndarray,     # (Q, 4) int32
    rects: jnp.ndarray,       # (R, 4) int32 (EMPTY-padded slots allowed)
    mask: jnp.ndarray | None = None,   # (Q,) bool/int Phase-1 filter
    *,
    tq: int = rk.DEFAULT_TQ,
    tr: int = rk.DEFAULT_TR,
    impl: str = DEFAULT_IMPL,
) -> jnp.ndarray:
    """Per-query overlap counts with optional Phase-1 gating.  (Q,) int32."""
    if impl not in IMPLS:
        raise ValueError(f"unknown impl {impl!r}; expected one of {IMPLS}")
    q = queries.shape[0]
    if q == 0:        # empty batch: a zero-extent grid has no tile to load
        return jnp.zeros((0,), jnp.int32)
    if mask is None:
        mask = jnp.ones((q,), jnp.int32)
    mask = mask.astype(jnp.int32)

    if impl == "xla":
        with jax.named_scope("overlap_counts_xla"):
            return _xla_counts(queries, rects, mask, tq, tr)

    qp = pad_rects_to(queries, tq)
    rp = pad_rects_to(rects, tr)
    maskp = jnp.pad(mask, (0, qp.shape[0] - q))
    q_coords = qp.T                       # (4, Qp)
    r_coords = rp.T                       # (4, Rp)
    qmbrs = tile_mbrs(qp, tq)
    rmbrs = tile_mbrs(rp, tr)
    if impl == "sparse":
        with jax.named_scope("overlap_counts_sparse"):
            nactive, tile_ids = build_active_tiles_device(qmbrs, rmbrs)
            out = rk.overlap_counts_sparse(
                q_coords, r_coords, maskp, nactive, tile_ids,
                tq=tq, tr=tr, interpret=_INTERPRET,
            )
    else:
        with jax.named_scope("overlap_counts_tiled"):
            out = rk.overlap_counts_tiled(
                q_coords, r_coords, qmbrs, rmbrs, maskp,
                tq=tq, tr=tr, interpret=_INTERPRET,
            )
    return out[:q]


@functools.partial(
    jax.jit, static_argnames=("tq", "tr", "impl")
)
def overlap_counts_fused(
    queries: jnp.ndarray,       # (Q, 4) int32 query batch
    r_coords: jnp.ndarray,      # (4, Rp) int32 — placement-time transpose
    r_tile_mbrs: jnp.ndarray,   # (Rp // tr, 4) int32 — placement-time MBRs
    cover_mbrs: jnp.ndarray,    # (K, 4) int32 covering L1 MBRs, EMPTY-padded
    *,
    tq: int = rk.DEFAULT_TQ,
    tr: int = rk.DEFAULT_TR,
    impl: str = DEFAULT_IMPL,
) -> jnp.ndarray:
    """Device-resident two-phase counts.  (Q,) int32.

    The rect side arrives pre-tiled (coords transposed, tile MBRs cached at
    placement); only the query side is tiled here, on device.  Phase-1 runs
    fused inside the kernel against ``cover_mbrs``.
    """
    if impl not in IMPLS:
        raise ValueError(f"unknown impl {impl!r}; expected one of {IMPLS}")
    q = queries.shape[0]
    if q == 0:        # empty batch: a zero-extent grid has no tile to load
        return jnp.zeros((0,), jnp.int32)
    if impl == "xla":
        with jax.named_scope("overlap_counts_fused_xla"):
            mask = ref.rect_overlap(
                queries[:, None, :], cover_mbrs[None, :, :]).any(axis=1)
            return ref.masked_overlap_counts_ref(queries, mask, r_coords.T)

    qp = pad_rects_to(queries, tq)
    q_coords = qp.T
    qmbrs = tile_mbrs(qp, tq)
    if impl == "sparse":
        with jax.named_scope("overlap_counts_fused_sparse"):
            nactive, tile_ids = build_active_tiles_device(
                qmbrs, r_tile_mbrs, cover_mbrs)
            out = rk.overlap_counts_sparse_fused(
                q_coords, r_coords, cover_mbrs, nactive, tile_ids,
                tq=tq, tr=tr, interpret=_INTERPRET,
            )
    else:
        with jax.named_scope("overlap_counts_fused_tiled"):
            out = rk.overlap_counts_tiled_fused(
                q_coords, r_coords, qmbrs, r_tile_mbrs, cover_mbrs,
                tq=tq, tr=tr, interpret=_INTERPRET,
            )
    return out[:q]


def _active_matrix_np(q_tile_mbrs: np.ndarray,
                      r_tile_mbrs: np.ndarray) -> np.ndarray:
    return (
        (q_tile_mbrs[:, None, 0] <= r_tile_mbrs[None, :, 2])
        & (r_tile_mbrs[None, :, 0] <= q_tile_mbrs[:, None, 2])
        & (q_tile_mbrs[:, None, 1] <= r_tile_mbrs[None, :, 3])
        & (r_tile_mbrs[None, :, 1] <= q_tile_mbrs[:, None, 3])
    )


def build_active_tiles(
    q_tile_mbrs: np.ndarray, r_tile_mbrs: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Host-side construction of the scalar-prefetch active-tile lists.

    For each query tile, the list of rect tiles whose MBRs overlap it,
    left-packed by a single stable argsort (active columns sort before dead
    ones; stability preserves ascending tile order).  Dead entries point at
    tile 0 and are masked by ``nactive``.
    """
    qo = _active_matrix_np(q_tile_mbrs, r_tile_mbrs)
    nactive = qo.sum(axis=1).astype(np.int32)
    max_active = max(int(nactive.max()), 1)
    order = np.argsort(~qo, axis=1, kind="stable")[:, :max_active]
    keep = np.arange(max_active)[None, :] < nactive[:, None]
    tile_ids = np.where(keep, order, 0).astype(np.int32)
    return nactive, tile_ids


def build_active_tiles_device(
    q_tile_mbrs: jnp.ndarray,
    r_tile_mbrs: jnp.ndarray,
    cover_mbrs: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Device-side active-tile lists (trace-safe jnp twin of
    :func:`build_active_tiles`).

    The list width is the static worst case (all rect tiles active); dead
    entries are skipped by the kernel's ``j < nactive`` guard.  When
    ``cover_mbrs`` is given, query tiles missing every cover MBR get an empty
    list — the tile-level half of the fused Phase-1 filter.
    """
    qo = ref.rect_overlap(
        q_tile_mbrs[:, None, :], r_tile_mbrs[None, :, :])     # (nq, nr)
    if cover_mbrs is not None:
        qcov = ref.rect_overlap(
            q_tile_mbrs[:, None, :], cover_mbrs[None, :, :]).any(axis=1)
        qo = qo & qcov[:, None]
    nq, nr = qo.shape
    nactive = qo.sum(axis=1, dtype=jnp.int32)
    order = jnp.argsort(
        jnp.logical_not(qo).astype(jnp.int32), axis=1, stable=True
    ).astype(jnp.int32)
    keep = jax.lax.broadcasted_iota(jnp.int32, (nq, nr), 1) < nactive[:, None]
    tile_ids = jnp.where(keep, order, 0)
    return nactive, tile_ids


# ---------------------------------------------------------------------------
# Query-surface dispatchers (repro.query): ID materialization, kNN, radius,
# aggregates.  Same shape as overlap_counts_fused — the rect side arrives
# placement-cached, only the query side is tiled here — with ``impl`` picking
# the Pallas kernel or the pure-jnp XLA twin.  ``impl="sparse"`` has no
# scalar-prefetch variant for these kinds and routes to the dense Pallas
# kernel.  The XLA twins chunk their (C, R) intermediates over queries; the
# intermediates stay on device (pallint PL113 bans host materialization).
# ---------------------------------------------------------------------------

_XLA_CHUNK = 256


def _point_tile_mbrs(points: jnp.ndarray, tq: int) -> jnp.ndarray:
    """Per-tile bboxes of a padded (2, Qp) point batch (as degenerate rects).

    Padding columns are zeros; they only widen the tile bbox toward the
    origin, which weakens distance pruning but never changes results.
    """
    prects = jnp.concatenate([points.T, points.T], axis=1)   # (Qp, 4)
    return tile_mbrs(prects, tq)


def _pad_points(points: jnp.ndarray, tq: int) -> jnp.ndarray:
    """EMPTY-analog padding for (Q, 2) point batches (zeros; results for
    padded rows are sliced off by the caller)."""
    q = points.shape[0]
    pad = (-q) % tq
    if pad == 0:
        return points
    return jnp.concatenate(
        [points, jnp.zeros((pad, 2), points.dtype)], axis=0)


def _xla_dist2(points: jnp.ndarray, rects: jnp.ndarray):
    """(C, R) squared f32 point-to-rect distances + validity — the XLA twin
    of :func:`repro.kernels.knn._pairwise_dist2` (same f32 op order)."""
    px = points[:, 0:1]
    py = points[:, 1:2]
    rx0 = rects[:, 0][None, :]
    ry0 = rects[:, 1][None, :]
    rx1 = rects[:, 2][None, :]
    ry1 = rects[:, 3][None, :]
    valid = (rx0 <= rx1) & (ry0 <= ry1)
    cx = jnp.clip(px, rx0, rx1)
    cy = jnp.clip(py, ry0, ry1)
    dx = px.astype(jnp.float32) - cx.astype(jnp.float32)
    dy = py.astype(jnp.float32) - cy.astype(jnp.float32)
    # same contraction barrier as _pairwise_dist2 (see knn.py): keeps
    # LLVM from FMA-fusing mul+add, so products round like NumPy's
    zero = jnp.float32(0.0)
    return jnp.maximum(dx * dx, zero) + jnp.maximum(dy * dy, zero), valid


def _xla_scatter_slots(hit: jnp.ndarray, r_ids: jnp.ndarray,
                       base: jnp.ndarray, kcap: int):
    """Left-pack matching IDs into global (C, kcap) slots, XLA-side.

    The first ``kcap`` matches per query (ascending placed order — a stable
    argsort pulls hit columns forward) land at slots ``base + local_rank``;
    slots >= kcap saturate.  Returns (slots_plus1, counts) matching the
    Pallas scatter kernels' contract.
    """
    c, nr = hit.shape
    counts = jnp.sum(hit, axis=1, dtype=jnp.int32)
    order = jnp.argsort(
        jnp.logical_not(hit).astype(jnp.int32), axis=1, stable=True
    ).astype(jnp.int32)
    width = min(kcap, nr)
    ordk = order[:, :width]
    iota_w = jax.lax.broadcasted_iota(jnp.int32, (c, width), 1)
    cand = jnp.where(iota_w < counts[:, None], r_ids[ordk] + 1, 0)
    if width < kcap:
        cand = jnp.pad(cand, ((0, 0), (0, kcap - width)))
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (c, kcap), 1)
    src = iota_k - base[:, None]
    in_range = (src >= 0) & (src < kcap)
    slots = jnp.where(
        in_range, jnp.take_along_axis(cand, jnp.clip(src, 0, kcap - 1),
                                      axis=1), 0)
    return slots, counts


def _scan_query_chunks(body, per_query_operands, q):
    """Run ``body(chunk_operands...)`` over fixed-size query chunks.

    Each operand is (Q, ...) and is zero-padded to a chunk multiple; body
    returns a pytree of (C, ...) leaves which are restacked to (Q, ...).
    """
    chunk = min(_XLA_CHUNK, max(q, 1))
    pad = (-q) % chunk
    padded = [
        jnp.pad(op, ((0, pad),) + ((0, 0),) * (op.ndim - 1))
        for op in per_query_operands
    ]
    stacked = [p.reshape((-1, chunk) + p.shape[1:]) for p in padded]

    def step(carry, ops_c):
        return carry, body(*ops_c)

    _, out = jax.lax.scan(step, None, tuple(stacked))
    return jax.tree_util.tree_map(
        lambda x: x.reshape((-1,) + x.shape[2:])[:q], out)


@functools.partial(
    jax.jit, static_argnames=("kcap", "tq", "tr", "impl")
)
def materialize_ids_fused(
    queries: jnp.ndarray,       # (Q, 4) int32 query batch
    r_coords: jnp.ndarray,      # (4, Rp) int32 placement-time transpose
    r_ids: jnp.ndarray,         # (Rp,) int32 source IDs (-1 padding)
    r_tile_mbrs: jnp.ndarray,   # (Rp // tr, 4) int32
    cover_mbrs: jnp.ndarray,    # (K, 4) int32, EMPTY-padded
    base: jnp.ndarray,          # (Q,) int32 per-query global slot offsets
    *,
    kcap: int = km.DEFAULT_KCAP,
    tq: int = km.DEFAULT_TQ,
    tr: int = km.DEFAULT_TR,
    impl: str = DEFAULT_IMPL,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Pass-2 range-query ID scatter.  Returns ``(slots_plus1 (Q, kcap),
    counts (Q,))`` — see :func:`repro.kernels.materialize.
    materialize_ids_tiled` for the slot encoding."""
    if impl not in IMPLS:
        raise ValueError(f"unknown impl {impl!r}; expected one of {IMPLS}")
    q = queries.shape[0]
    if q == 0:
        return (jnp.zeros((0, kcap), jnp.int32), jnp.zeros((0,), jnp.int32))
    if impl == "xla":
        with jax.named_scope("materialize_ids_xla"):
            rects = r_coords.T

            def body(qc, bc):
                hit = ref.rect_overlap(qc[:, None, :], rects[None, :, :])
                mask = ref.rect_overlap(
                    qc[:, None, :], cover_mbrs[None, :, :]).any(axis=1)
                return _xla_scatter_slots(hit & mask[:, None], r_ids, bc,
                                          kcap)
            return _scan_query_chunks(body, (queries, base), q)
    qp = pad_rects_to(queries, tq)
    basep = jnp.pad(base, (0, qp.shape[0] - q))
    with jax.named_scope("materialize_ids_tiled"):
        slots, counts = km.materialize_ids_tiled(
            qp.T, r_coords, r_ids, tile_mbrs(qp, tq), r_tile_mbrs,
            cover_mbrs, basep, kcap=kcap, tq=tq, tr=tr,
            interpret=_INTERPRET,
        )
    return slots[:q], counts[:q]


@functools.partial(
    jax.jit, static_argnames=("kcap", "tq", "tr", "impl")
)
def materialize_radius_fused(
    points: jnp.ndarray,        # (Q, 2) int32 query points
    radii: jnp.ndarray,         # (Q,) int32 (< 0 marks padding)
    r_coords: jnp.ndarray,      # (4, Rp) int32
    r_ids: jnp.ndarray,         # (Rp,) int32
    r_tile_mbrs: jnp.ndarray,   # (Rp // tr, 4) int32
    base: jnp.ndarray,          # (Q,) int32 global slot offsets
    *,
    kcap: int = km.DEFAULT_KCAP,
    tq: int = km.DEFAULT_TQ,
    tr: int = km.DEFAULT_TR,
    impl: str = DEFAULT_IMPL,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Radius-query (closed f32 ball) ID scatter; contract as
    :func:`materialize_ids_fused`."""
    if impl not in IMPLS:
        raise ValueError(f"unknown impl {impl!r}; expected one of {IMPLS}")
    q = points.shape[0]
    if q == 0:
        return (jnp.zeros((0, kcap), jnp.int32), jnp.zeros((0,), jnp.int32))
    if impl == "xla":
        with jax.named_scope("materialize_radius_xla"):
            rects = r_coords.T

            def body(pc, rc, bc):
                d2, valid = _xla_dist2(pc, rects)
                r2 = rc.astype(jnp.float32) * rc.astype(jnp.float32)
                hit = valid & (rc >= 0)[:, None] & (d2 <= r2[:, None])
                return _xla_scatter_slots(hit, r_ids, bc, kcap)
            return _scan_query_chunks(body, (points, radii, base), q)
    pp = _pad_points(points, tq)
    radp = jnp.pad(radii, (0, pp.shape[0] - q), constant_values=-1)
    basep = jnp.pad(base, (0, pp.shape[0] - q))
    with jax.named_scope("materialize_radius_tiled"):
        slots, counts = km.materialize_radius_tiled(
            pp.T, radp, r_coords, r_ids, _point_tile_mbrs(pp.T, tq),
            r_tile_mbrs, basep, kcap=kcap, tq=tq, tr=tr,
            interpret=_INTERPRET,
        )
    return slots[:q], counts[:q]


@functools.partial(
    jax.jit, static_argnames=("k", "tq", "tr", "impl")
)
def knn_fused(
    points: jnp.ndarray,        # (Q, 2) int32 query points
    r_coords: jnp.ndarray,      # (4, Rp) int32
    r_ids: jnp.ndarray,         # (Rp,) int32
    r_tile_mbrs: jnp.ndarray,   # (Rp // tr, 4) int32
    *,
    k: int,
    tq: int = kk.DEFAULT_TQ,
    tr: int = kk.DEFAULT_TR,
    impl: str = DEFAULT_IMPL,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-device kNN.  Returns ``(dists (Q, k) f32 ascending, ids (Q, k)
    i32)`` with the ``INT32_MAX`` empty sentinel (ties broken by source ID;
    see :mod:`repro.kernels.knn` for the f32-exactness contract)."""
    if impl not in IMPLS:
        raise ValueError(f"unknown impl {impl!r}; expected one of {IMPLS}")
    q = points.shape[0]
    if q == 0:
        return (jnp.zeros((0, k), jnp.float32), jnp.zeros((0, k), jnp.int32))
    if impl == "xla":
        with jax.named_scope("knn_xla"):
            rects = r_coords.T

            def body(pc):
                d2, valid = _xla_dist2(pc, rects)
                d2 = jnp.where(valid, d2, jnp.inf)
                ids = jnp.where(valid, r_ids[None, :], INT32_MAX)
                ids = jnp.broadcast_to(ids, d2.shape).astype(jnp.int32)
                if d2.shape[1] < k:
                    padw = k - d2.shape[1]
                    d2 = jnp.pad(d2, ((0, 0), (0, padw)),
                                 constant_values=jnp.inf)
                    ids = jnp.pad(ids, ((0, 0), (0, padw)),
                                  constant_values=INT32_MAX)
                ds, si = jax.lax.sort((d2, ids), dimension=1, num_keys=2)
                return ds[:, :k], si[:, :k]
            return _scan_query_chunks(body, (points,), q)
    pp = _pad_points(points, tq)
    with jax.named_scope("knn_tiled"):
        dists, ids = kk.knn_tiled(
            pp.T, r_coords, r_ids, _point_tile_mbrs(pp.T, tq), r_tile_mbrs,
            k=k, tq=tq, tr=tr, interpret=_INTERPRET,
        )
    return dists[:q], ids[:q]


@functools.partial(
    jax.jit, static_argnames=("tq", "tr", "impl")
)
def aggregate_fused(
    queries: jnp.ndarray,       # (Q, 4) int32 query batch
    r_coords: jnp.ndarray,      # (4, Rp) int32
    r_tile_mbrs: jnp.ndarray,   # (Rp // tr, 4) int32
    cover_mbrs: jnp.ndarray,    # (K, 4) int32, EMPTY-padded
    *,
    tq: int = rk.DEFAULT_TQ,
    tr: int = rk.DEFAULT_TR,
    impl: str = DEFAULT_IMPL,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """On-fabric per-device aggregate partials: ``(counts (Q,) i32,
    sums (3, Q) f32 [Σ(x0+x1), Σ(y0+y1), Σ area], bbox (4, Q) i32)``."""
    if impl not in IMPLS:
        raise ValueError(f"unknown impl {impl!r}; expected one of {IMPLS}")
    q = queries.shape[0]
    if q == 0:
        return (jnp.zeros((0,), jnp.int32), jnp.zeros((3, 0), jnp.float32),
                jnp.zeros((4, 0), jnp.int32))
    if impl == "xla":
        with jax.named_scope("aggregate_xla"):
            rects = r_coords.T

            def body(qc):
                hit = ref.rect_overlap(qc[:, None, :], rects[None, :, :])
                mask = ref.rect_overlap(
                    qc[:, None, :], cover_mbrs[None, :, :]).any(axis=1)
                hit = hit & mask[:, None]
                rf = rects.astype(jnp.float32)
                zero = jnp.float32(0.0)
                sum_cx = jnp.sum(
                    jnp.where(hit, (rf[:, 0] + rf[:, 2])[None, :], zero),
                    axis=1)
                sum_cy = jnp.sum(
                    jnp.where(hit, (rf[:, 1] + rf[:, 3])[None, :], zero),
                    axis=1)
                area = ((rf[:, 2] - rf[:, 0]) * (rf[:, 3] - rf[:, 1]))
                sum_area = jnp.sum(jnp.where(hit, area[None, :], zero),
                                   axis=1)
                cnt = jnp.sum(hit, axis=1, dtype=jnp.int32)
                xmin = jnp.min(
                    jnp.where(hit, rects[:, 0][None, :], INT32_MAX), axis=1)
                ymin = jnp.min(
                    jnp.where(hit, rects[:, 1][None, :], INT32_MAX), axis=1)
                xmax = jnp.max(
                    jnp.where(hit, rects[:, 2][None, :], INT32_MIN), axis=1)
                ymax = jnp.max(
                    jnp.where(hit, rects[:, 3][None, :], INT32_MIN), axis=1)
                return (cnt, jnp.stack([sum_cx, sum_cy, sum_area], axis=0),
                        jnp.stack([xmin, ymin, xmax, ymax], axis=0))
            cnt, sums, bbox = _scan_query_chunks_t(body, queries, q)
            return cnt, sums, bbox
    qp = pad_rects_to(queries, tq)
    with jax.named_scope("aggregate_tiled"):
        counts, sums, bbox = ka.aggregate_tiled(
            qp.T, r_coords, tile_mbrs(qp, tq), r_tile_mbrs, cover_mbrs,
            tq=tq, tr=tr, interpret=_INTERPRET,
        )
    return counts[:q], sums[:, :q], bbox[:, :q]


def _scan_query_chunks_t(body, queries, q):
    """Like :func:`_scan_query_chunks` for bodies whose outputs carry the
    query axis *last* (the (3, C) sums / (4, C) bbox layout)."""
    chunk = min(_XLA_CHUNK, max(q, 1))
    pad = (-q) % chunk
    qp = jnp.pad(queries, ((0, pad), (0, 0)))

    def step(carry, qc):
        return carry, body(qc)

    _, (cnt, sums, bbox) = jax.lax.scan(
        step, None, qp.reshape(-1, chunk, 4))
    cnt = cnt.reshape(-1)[:q]
    sums = jnp.moveaxis(sums, 0, 1).reshape(3, -1)[:, :q]
    bbox = jnp.moveaxis(bbox, 0, 1).reshape(4, -1)[:, :q]
    return cnt, sums, bbox


def overlap_counts_sparse_host(
    queries: np.ndarray,
    rects: np.ndarray,
    mask: np.ndarray | None = None,
    *,
    tq: int = rk.DEFAULT_TQ,
    tr: int = rk.DEFAULT_TR,
) -> jnp.ndarray:
    """Sparse (scalar-prefetch) path; tile lists built on host from MBRs.

    Kept as the pre-cache reference pipeline: every call re-derives all tile
    metadata on the host — exactly the per-batch cost the device-resident
    engine amortizes away (measured in benchmarks/regress.py).  The metadata
    is built in pure NumPy and crosses to the device exactly once; the old
    ``np.asarray(pad_rects_to(jnp.asarray(...)))`` host→device→host bounce
    was pallint PL108's first catch.
    """
    q = queries.shape[0]
    if mask is None:
        mask = np.ones((q,), np.int32)
    qp = pad_rects_to_np(np.asarray(queries, np.int32), tq)
    rp = pad_rects_to_np(np.asarray(rects, np.int32), tr)
    maskp = np.pad(np.asarray(mask, np.int32), (0, qp.shape[0] - q))
    qmbrs = tile_mbrs_np(qp, tq)
    rmbrs = tile_mbrs_np(rp, tr)
    nactive, tile_ids = build_active_tiles(qmbrs, rmbrs)
    out = rk.overlap_counts_sparse(
        jnp.asarray(qp.T), jnp.asarray(rp.T), jnp.asarray(maskp),
        jnp.asarray(nactive), jnp.asarray(tile_ids),
        tq=tq, tr=tr, interpret=_INTERPRET,
    )
    return out[:q]
