"""Jitted public wrappers around the rectangle-intersection kernels.

``overlap_counts(queries, rects, mask)`` is the engine-facing op.  Three
execution paths, selected by ``impl=``:

* ``"pallas"``  — the Pallas TPU kernel (interpret=True on CPU containers).
* ``"sparse"``  — the scalar-prefetch Pallas kernel with host-built active
                  tile lists (DMA-level pruning; §Perf hillclimb kernel).
* ``"xla"``     — pure-jnp tiled equivalent (same math, XLA codegen).  This
                  is the fast path on CPU and the cross-check on TPU.

All paths are exact-int equal to :mod:`repro.kernels.ref`.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import rect_intersect as rk
from repro.kernels import ref

INT32_MAX = 2**31 - 1
INT32_MIN = -(2**31)

# On CPU containers the Pallas kernel runs in interpret mode (the kernel body
# executes in Python) — correct but slow, so engines default to the XLA path
# unless REPRO_KERNEL_IMPL overrides it.
DEFAULT_IMPL = os.environ.get(
    "REPRO_KERNEL_IMPL",
    "xla" if jax.default_backend() == "cpu" else "pallas",
)
_INTERPRET = jax.default_backend() == "cpu"


def pad_rects_to(rects: jnp.ndarray, multiple: int) -> jnp.ndarray:
    """Pad an (N, 4) rect array with EMPTY sentinels to a multiple."""
    n = rects.shape[0]
    pad = (-n) % multiple
    if pad == 0:
        return rects
    empty = jnp.array([INT32_MAX, INT32_MAX, INT32_MIN, INT32_MIN],
                      dtype=rects.dtype)
    return jnp.concatenate([rects, jnp.tile(empty, (pad, 1))], axis=0)


def tile_mbrs(rects: jnp.ndarray, tile: int) -> jnp.ndarray:
    """Per-tile MBRs of an (Np, 4) rect array, Np % tile == 0 → (Np/tile, 4).

    Sentinel-safe: empty slots contribute INT32_MAX minima / INT32_MIN maxima
    and so never widen a tile MBR; an all-empty tile gets the EMPTY MBR and is
    pruned everywhere."""
    r = rects.reshape(-1, tile, 4)
    return jnp.concatenate(
        [r[..., :2].min(axis=1), r[..., 2:].max(axis=1)], axis=-1
    )


def _xla_counts(queries, rects, mask, tq, tr):
    del tq, tr
    return ref.masked_overlap_counts_ref(queries, mask, rects)


@functools.partial(
    jax.jit, static_argnames=("tq", "tr", "impl")
)
def overlap_counts(
    queries: jnp.ndarray,     # (Q, 4) int32
    rects: jnp.ndarray,       # (R, 4) int32 (EMPTY-padded slots allowed)
    mask: jnp.ndarray | None = None,   # (Q,) bool/int Phase-1 filter
    *,
    tq: int = rk.DEFAULT_TQ,
    tr: int = rk.DEFAULT_TR,
    impl: str = DEFAULT_IMPL,
) -> jnp.ndarray:
    """Per-query overlap counts with optional Phase-1 gating.  (Q,) int32."""
    q = queries.shape[0]
    if mask is None:
        mask = jnp.ones((q,), jnp.int32)
    mask = mask.astype(jnp.int32)

    if impl == "xla":
        return _xla_counts(queries, rects, mask, tq, tr)

    qp = pad_rects_to(queries, tq)
    rp = pad_rects_to(rects, tr)
    maskp = jnp.pad(mask, (0, qp.shape[0] - q))
    q_coords = qp.T                       # (4, Qp)
    r_coords = rp.T                       # (4, Rp)
    qmbrs = tile_mbrs(qp, tq)
    rmbrs = tile_mbrs(rp, tr)
    out = rk.overlap_counts_tiled(
        q_coords, r_coords, qmbrs, rmbrs, maskp,
        tq=tq, tr=tr, interpret=_INTERPRET,
    )
    return out[:q]


def build_active_tiles(
    q_tile_mbrs: np.ndarray, r_tile_mbrs: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Host-side construction of the scalar-prefetch active-tile lists.

    For each query tile, the list of rect tiles whose MBRs overlap it.
    Dead entries point at tile 0 and are masked by ``nactive``."""
    qo = (
        (q_tile_mbrs[:, None, 0] <= r_tile_mbrs[None, :, 2])
        & (r_tile_mbrs[None, :, 0] <= q_tile_mbrs[:, None, 2])
        & (q_tile_mbrs[:, None, 1] <= r_tile_mbrs[None, :, 3])
        & (r_tile_mbrs[None, :, 1] <= q_tile_mbrs[:, None, 3])
    )
    nq, nr = qo.shape
    nactive = qo.sum(axis=1).astype(np.int32)
    max_active = max(int(nactive.max()), 1)
    tile_ids = np.zeros((nq, max_active), dtype=np.int32)
    for i in range(nq):
        ids = np.nonzero(qo[i])[0]
        tile_ids[i, : ids.size] = ids
    return nactive, tile_ids


def overlap_counts_sparse_host(
    queries: np.ndarray,
    rects: np.ndarray,
    mask: np.ndarray | None = None,
    *,
    tq: int = rk.DEFAULT_TQ,
    tr: int = rk.DEFAULT_TR,
) -> jnp.ndarray:
    """Sparse (scalar-prefetch) path; tile lists built on host from MBRs."""
    q = queries.shape[0]
    if mask is None:
        mask = np.ones((q,), np.int32)
    qp = np.asarray(pad_rects_to(jnp.asarray(queries), tq))
    rp = np.asarray(pad_rects_to(jnp.asarray(rects), tr))
    maskp = np.pad(np.asarray(mask, np.int32), (0, qp.shape[0] - q))
    qmbrs = np.asarray(tile_mbrs(jnp.asarray(qp), tq))
    rmbrs = np.asarray(tile_mbrs(jnp.asarray(rp), tr))
    nactive, tile_ids = build_active_tiles(qmbrs, rmbrs)
    out = rk.overlap_counts_sparse(
        jnp.asarray(qp.T), jnp.asarray(rp.T), jnp.asarray(maskp),
        jnp.asarray(nactive), jnp.asarray(tile_ids),
        tq=tq, tr=tr, interpret=_INTERPRET,
    )
    return out[:q]
