"""Pallas TPU kNN kernel with tile-MBR min-distance pruning (repro.query).

Metric: **squared point-to-rect distance in float32**.  Coordinates are
int32 (the paper's fixed-precision grid); the closest point of a rect to a
query point is obtained with an exact int32 clip, and only the final
subtraction/multiply/add run in float32:

    cx = clip(px, rx0, rx1)          # exact int32
    d2 = (f32(px) - f32(cx))^2 + (f32(py) - f32(cy))^2

Every implementation of the metric — this kernel, the XLA twin in
``repro.kernels.ops``, and the NumPy oracle in ``repro.query.oracle`` —
performs the *same* float32 operations in the same order, so results are
bit-equal by IEEE-754 determinism: "NumPy-oracle-exact" holds even though
the metric itself rounds (f32 conversion of |coord| > 2^24 loses low bits,
identically everywhere).

Ties are broken by ascending source ID: candidates are ordered by the
lexicographic key ``(d2, id)`` via a two-key ``jax.lax.sort``.  Absent
candidates carry ``(inf, INT32_MAX)`` so they sort last; the pipeline maps
the ``INT32_MAX`` sentinel to ``-1`` after the cross-device merge.

Pruning: a rect tile whose MBR min-distance to the query-tile bbox exceeds
every query's current k-th distance cannot contribute and is skipped.  The
bound is computed in float32 from a different expression than the per-point
metric, so it is deflated by ``_PRUNE_MARGIN`` (a ~10 ulp guard band, far
wider than the <=4 ulp relative error of either float32 chain) — pruning can
only drop tiles *strictly* outside the current frontier and never changes
results.  State (the running (TQ, k) frontier) lives in the output blocks,
which Pallas revisits for every j at the same i.

Grid: ``(num_query_tiles, num_rect_tiles)``, rect axis innermost so the
frontier tightens monotonically as tiles stream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TQ = 256
DEFAULT_TR = 512

_INT32_MAX = 2**31 - 1

# Deflates the f32 tile min-distance bound before comparing against the f32
# frontier: both chains carry <= ~4 ulp (~5e-7 relative) error, so a 1e-5
# relative guard band keeps pruning strictly conservative.
_PRUNE_MARGIN = 1.0 - 1e-5


def _pairwise_dist2(p_ref, r_ref):
    """Squared f32 point-to-rect distances of one (point-tile, rect-tile).

    p_ref : (2, TQ) int32 point coordinates
    r_ref : (4, TR) int32 rect coordinates
    Returns ``(d2 (TQ, TR) float32, valid (1, TR) bool)``; d2 is garbage on
    invalid (EMPTY sentinel) rects — mask with ``valid``.
    """
    px = p_ref[0, :][:, None]
    py = p_ref[1, :][:, None]
    rx0 = r_ref[0, :][None, :]
    ry0 = r_ref[1, :][None, :]
    rx1 = r_ref[2, :][None, :]
    ry1 = r_ref[3, :][None, :]
    valid = (rx0 <= rx1) & (ry0 <= ry1)
    cx = jnp.clip(px, rx0, rx1)          # exact int32, no overflow
    cy = jnp.clip(py, ry0, ry1)
    dx = px.astype(jnp.float32) - cx.astype(jnp.float32)
    dy = py.astype(jnp.float32) - cy.astype(jnp.float32)
    # max(sq, 0) is the identity on squares but is a contraction barrier:
    # without it LLVM fuses mul+add into an FMA inside the XLA:CPU loop
    # fusion (invisible in HLO; optimization_barrier does not stop it),
    # which skips one rounding and breaks bit-equality with the NumPy
    # oracle whenever dx*dx > 2**24.  The NaN-strict maximum cannot be
    # folded away, so both products round separately, exactly like NumPy.
    zero = jnp.float32(0.0)
    return jnp.maximum(dx * dx, zero) + jnp.maximum(dy * dy, zero), valid


def _tile_min_dist2(qbox, rmbr):
    """Conservative f32 lower bound on d2 between two boxes of shape (4,)."""
    zero = jnp.float32(0.0)
    dx = jnp.maximum(
        jnp.maximum(rmbr[0].astype(jnp.float32) - qbox[2].astype(jnp.float32),
                    qbox[0].astype(jnp.float32) - rmbr[2].astype(jnp.float32)),
        zero)
    dy = jnp.maximum(
        jnp.maximum(rmbr[1].astype(jnp.float32) - qbox[3].astype(jnp.float32),
                    qbox[1].astype(jnp.float32) - rmbr[3].astype(jnp.float32)),
        zero)
    return dx * dx + dy * dy


def _knn_kernel(p_ref, r_ref, id_ref, qmbr_ref, rmbr_ref, dist_ref, idx_ref):
    """One (point-tile, rect-tile) grid step of the running top-k merge.

    p_ref    : (2, TQ) int32 — query point coordinates
    r_ref    : (4, TR) int32 — placed rect coordinates
    id_ref   : (1, TR) int32 — source IDs (-1 on padding slots)
    qmbr_ref : (1, 4) int32 — bbox of this point tile
    rmbr_ref : (1, 4) int32 — MBR of this rect tile
    dist_ref : (TQ, k) f32 out — running k smallest d2 (ascending)
    idx_ref  : (TQ, k) i32 out — their source IDs (INT32_MAX = empty)
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        dist_ref[...] = jnp.full_like(dist_ref, jnp.inf)
        idx_ref[...] = jnp.full_like(idx_ref, _INT32_MAX)

    rmbr = rmbr_ref[0]
    tile_valid = (rmbr[0] <= rmbr[2]) & (rmbr[1] <= rmbr[3])
    kth_max = jnp.max(dist_ref[:, dist_ref.shape[1] - 1])
    mind2 = _tile_min_dist2(qmbr_ref[0], rmbr)
    prune_ok = tile_valid & (mind2 * _PRUNE_MARGIN <= kth_max)

    @pl.when(prune_ok)
    def _compute():
        k = dist_ref.shape[1]
        d2, valid = _pairwise_dist2(p_ref, r_ref)
        d2 = jnp.where(valid, d2, jnp.inf)
        cand_ids = jnp.where(valid, id_ref[...], _INT32_MAX)      # (1, TR)
        idm = jnp.broadcast_to(cand_ids, d2.shape).astype(jnp.int32)
        dcat = jnp.concatenate([dist_ref[...], d2], axis=1)
        icat = jnp.concatenate([idx_ref[...], idm], axis=1)
        ds, ids = jax.lax.sort((dcat, icat), dimension=1, num_keys=2)
        dist_ref[...] = ds[:, :k]
        idx_ref[...] = ids[:, :k]


@functools.partial(
    jax.jit, static_argnames=("k", "tq", "tr", "interpret")
)
def knn_tiled(
    p_coords: jnp.ndarray,     # (2, Qp) int32, Qp % tq == 0
    r_coords: jnp.ndarray,     # (4, Rp) int32, Rp % tr == 0
    r_ids: jnp.ndarray,        # (Rp,) int32 source IDs
    q_tile_mbrs: jnp.ndarray,  # (Qp // tq, 4) int32 point-tile bboxes
    r_tile_mbrs: jnp.ndarray,  # (Rp // tr, 4) int32
    *,
    k: int,
    tq: int = DEFAULT_TQ,
    tr: int = DEFAULT_TR,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """k nearest rects per query point.

    Returns ``(dists (Qp, k) f32 ascending, ids (Qp, k) i32)``; slots past
    the number of available rects hold ``(inf, INT32_MAX)`` — callers map
    the sentinel to -1 after any cross-device merge.
    """
    qp, rp = p_coords.shape[1], r_coords.shape[1]
    assert qp % tq == 0 and rp % tr == 0, (qp, tq, rp, tr)
    nq, nr = qp // tq, rp // tr
    dists, ids = pl.pallas_call(
        _knn_kernel,
        grid=(nq, nr),
        in_specs=[
            pl.BlockSpec((2, tq), lambda i, j: (0, i)),
            pl.BlockSpec((4, tr), lambda i, j: (0, j)),
            pl.BlockSpec((1, tr), lambda i, j: (0, j)),
            pl.BlockSpec((1, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 4), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qp, k), jnp.float32),
            jax.ShapeDtypeStruct((qp, k), jnp.int32),
        ],
        interpret=interpret,
    )(p_coords, r_coords, r_ids[None, :], q_tile_mbrs, r_tile_mbrs)
    return dists, ids
