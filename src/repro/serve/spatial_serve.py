"""Fault-tolerant always-on spatial serving (DESIGN.md Sec 11).

Everything in :mod:`repro.core.engine` assumes a perfect offline run: one
``query(all_queries)`` call, no request queue, no deadlines, no recovery
from a lost device or a corrupted kernel output.  This module is the bridge
to the ROADMAP's "millions of users" serving layer, with robustness as the
headline:

* **Bounded request queue + admission control** — :meth:`SpatialServer.submit`
  validates each rect (strict mode: malformed requests are refused, never
  reinterpreted), sheds explicitly when the queue is full, and sheds at
  admission when the EWMA batch latency predicts the deadline cannot be met
  (backpressure as an explicit signal, not an unbounded queue).
* **Micro-batch formation into the one compiled shape** — requests are
  drained into ``(batch_size, 4)`` batches, Morton-ordered per batch for
  tile-MBR locality (counts are un-permuted on completion), EMPTY-padded to
  the fixed shape so the jitted step never retraces.
* **Watchdog + capped exponential backoff** — each fast-path batch runs
  under a watchdog timeout (PrIM shows wide per-DPU latency variance;
  stragglers are the norm, not the exception); failures retry a *bounded*
  number of times with capped backoff (pallint PL110 machine-checks that
  serving loops stay bounded).
* **Graceful degradation** — after retries are exhausted (device loss,
  persistent stragglers, corrupted output) the server degrades to the exact
  NumPy reference kernel (:func:`repro.kernels.ref.overlap_counts_np_chunked`)
  over the host copy of the leaf rects, and probes the fast path periodically
  to recover.  In healthy steady state a sampled oracle cross-check guards
  against silent corruption; a failed cross-check is treated as a fault.
* **Health/metrics surface** — queue depth, shed/expired counts, retries,
  degradations/recoveries, per-fault counters, and p50/p90/p99 batch and
  request latency.  All of it is backed by a per-server
  :class:`repro.obs.metrics.Registry` (``server.registry``): events and
  faults are labeled counter families (``serve_events_total{kind=...}``,
  ``serve_faults_total{kind=...}``), latencies are fixed-bucket histograms
  (``serve_batch_latency_seconds``, ``serve_request_latency_seconds``,
  ``serve_queue_wait_seconds``) whose percentiles are interpolated estimates
  over *all observations since server construction* (cumulative window,
  Prometheus semantics — not a sliding ring).  :meth:`SpatialServer.metrics`
  keeps its original dict shape on top of the registry, and
  ``server.registry.prometheus_text()`` exports the same numbers for
  scraping.  Batches and fault-handling transitions also emit spans/events
  into the :mod:`repro.obs.trace` tracer when it is enabled (DESIGN.md
  Sec 12).

Fault injection for all of the above lives in :mod:`repro.testing.chaos`,
which wraps the two seams this module exposes (``_step`` — the jitted query
step, and ``_place`` — batch staging via ``jax.device_put``).

In no-fault steady state the served counts are bit-equal to
``BroadcastEngine.query``: same step, same padding, same Morton ordering.
"""
from __future__ import annotations

import collections
import concurrent.futures
import dataclasses
import threading
import time
import warnings
from typing import Callable

import numpy as np

import jax

from repro.core.engine import (
    EMPTY_RECT, morton_order, validate_queries)
from repro.kernels import ref
from repro.obs import metrics as obs_metrics
from repro.obs import phases as obs_phases
from repro.obs import trace as obs_trace

HEALTHY = "healthy"
DEGRADED = "degraded"

STATUS_PENDING = "pending"
STATUS_OK = "ok"
STATUS_SHED = "shed"
STATUS_EXPIRED = "expired"
STATUS_CANCELLED = "cancelled"

PATH_FAST = "fast"
PATH_REF = "ref"


class WatchdogTimeout(RuntimeError):
    """The fast-path batch exceeded the watchdog deadline (straggler)."""


class CorruptOutputError(RuntimeError):
    """The fast path returned counts that failed sanity or cross-check."""


class SpatialTicket:
    """One submitted request: completion event + result fields.

    ``status`` is one of ``ok`` / ``shed`` / ``expired`` / ``cancelled``
    (or ``pending`` until completed); ``path`` records which execution path
    answered (``fast`` or ``ref``), ``reason`` why a request was shed or
    cancelled."""

    __slots__ = ("rect", "submit_t", "deadline", "status", "reason",
                 "count", "path", "latency_s", "_event")

    def __init__(self, rect: np.ndarray, submit_t: float, deadline: float):
        self.rect = rect
        self.submit_t = submit_t
        self.deadline = deadline
        self.status = STATUS_PENDING
        self.reason = None
        self.count = None
        self.path = None
        self.latency_s = None
        self._event = threading.Event()

    @property
    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: float | None = None) -> bool:
        """Block until completed; returns False on wait timeout."""
        return self._event.wait(timeout)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Serving-loop policy knobs (every bound the chaos suite exercises)."""

    batch_size: int = 256           # the one compiled (bs, 4) shape
    max_queue: int = 1024           # bounded queue; beyond this, shed
    default_deadline_s: float = 1.0
    watchdog_s: float = 2.0         # per-attempt fast-path time budget
    max_retries: int = 3            # bounded retry (PL110 doctrine)
    backoff_base_s: float = 0.02    # capped exponential backoff
    backoff_cap_s: float = 0.5
    crosscheck_every: int = 64      # healthy-state sampled oracle check
    crosscheck_samples: int = 8
    probe_every: int = 8            # degraded-state fast-path probe cadence
    sort_batches: bool = True       # per-batch Morton ordering


def _engine_bindings(engine):
    """Extract (step, operands, rep_sharding, host_rects) from an engine.

    Works for both ``BroadcastEngine`` and ``SubtreeEngine`` — the step
    arity and the replicated query sharding are identical; only the operand
    names and the host-side rect layout differ."""
    if hasattr(engine, "leaf_coords"):          # BroadcastEngine
        operands = (engine.leaf_coords, engine.rect_tile_mbrs,
                    engine.cover_mbrs)
        flat = engine.layout.leaf_rects_flat
    else:                                       # SubtreeEngine
        operands = (engine.dev_coords, engine.dev_tile_mbrs, engine.dev_mbrs)
        flat = engine.layout.rects.reshape(-1, 4)
    host_rects = flat[flat[:, 0] <= flat[:, 2]]
    return engine._step, operands, engine._rep_sh, host_rects


class SpatialServer:
    """Always-on serving loop over a spatial engine's compiled query step.

    Single-consumer: ``pump``/``drain`` must be driven from one thread
    (either the caller's, or the background worker started by
    :meth:`start`).  ``submit`` is thread-safe.
    """

    def __init__(
        self,
        engine,
        config: ServeConfig | None = None,
        *,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
        warmup: bool = True,
        registry: obs_metrics.Registry | None = None,
    ):
        self.engine = engine
        self.config = config or ServeConfig()
        self._clock = clock
        self._sleep = sleep

        # the two chaos seams: the jitted step and batch staging
        self._step, self._operands, self._rep_sh, self._host_rects = (
            _engine_bindings(engine))
        self._place = jax.device_put

        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._queue: collections.deque[SpatialTicket] = collections.deque()
        self._accepting = True
        self._stop_evt = threading.Event()
        self._thread: threading.Thread | None = None

        self.health = HEALTHY
        self._served_batches = 0
        self._degraded_batches_since = 0
        self._batch_ewma_s: float | None = None
        self._last_fault: str | None = None

        # registry-backed metrics surface (per-server by default, so two
        # servers never share series; pass a registry to aggregate)
        self.registry = registry if registry is not None else (
            obs_metrics.Registry())
        self._events = self.registry.counter(
            "serve_events_total",
            "serving-loop events by kind (submitted/served/shed_*/...)")
        self._fault_counter = self.registry.counter(
            "serve_faults_total", "fast-path faults by kind")
        self._health_gauge = self.registry.gauge(
            "serve_healthy", "1 while on the fast path, 0 while degraded")
        self._health_gauge.set(1.0)
        self._queue_gauge = self.registry.gauge(
            "serve_queue_depth", "current admitted-but-unserved requests")
        self._batch_hist = self.registry.histogram(
            "serve_batch_latency_seconds",
            "wall time of one served micro-batch (execute only)")
        self._req_hist = self.registry.histogram(
            "serve_request_latency_seconds",
            "submit-to-completion latency of served requests")
        self._wait_hist = self.registry.histogram(
            "serve_queue_wait_seconds",
            "submit-to-batch-formation wait of served requests")

        bs = self.config.batch_size
        self._pad_rect = np.asarray(EMPTY_RECT, dtype=np.int32).reshape(1, 4)
        if warmup:
            self._warmup(bs)

    # ------------------------------------------------------------------ admit

    def submit(self, rect, *, deadline_s: float | None = None) -> SpatialTicket:
        """Admit one range-count request.  Always returns a ticket; a shed
        request comes back already completed with ``status='shed'``."""
        arr = np.asarray(rect)
        if arr.shape == (4,):
            arr = arr.reshape(1, 4)
        validated = validate_queries(
            arr, strict=True, where="SpatialServer.submit")[0]
        now = self._clock()
        if deadline_s is None:
            deadline_s = self.config.default_deadline_s
        ticket = SpatialTicket(validated, now, now + deadline_s)
        self._events.inc(kind="submitted")
        if deadline_s <= 0:
            # Already expired at submit: shed immediately instead of letting
            # a dead request occupy a batch slot until pump() notices.
            return self._shed(ticket, "deadline", now)
        with self._lock:
            if not self._accepting:
                return self._shed(ticket, "stopped", now)
            if len(self._queue) >= self.config.max_queue:
                return self._shed(ticket, "capacity", now)
            ewma = self._batch_ewma_s
            if ewma is not None:
                batches_ahead = len(self._queue) // self.config.batch_size + 1
                if now + batches_ahead * ewma > ticket.deadline:
                    return self._shed(ticket, "deadline", now)
            self._queue.append(ticket)
            self._queue_gauge.set(len(self._queue))
            self._not_empty.notify()
        return ticket

    def _shed(self, ticket: SpatialTicket, reason: str, now: float
              ) -> SpatialTicket:
        self._events.inc(kind=f"shed_{reason}")
        obs_trace.event("serve.shed", reason=reason)
        ticket.status = STATUS_SHED
        ticket.reason = reason
        ticket.latency_s = now - ticket.submit_t
        ticket._event.set()
        return ticket

    def cancel(self, ticket: SpatialTicket, reason: str = "cancelled") -> bool:
        """Withdraw a still-queued request (e.g. a hedged duplicate whose
        twin already answered).  Returns True iff the ticket was removed
        before batch formation; a ticket already being served (or done)
        cannot be cancelled and keeps its eventual result."""
        with self._lock:
            try:
                self._queue.remove(ticket)
            except ValueError:
                return False
            self._queue_gauge.set(len(self._queue))
        self._events.inc(kind="cancelled")
        obs_trace.event("serve.cancel", reason=reason)
        ticket.status = STATUS_CANCELLED
        ticket.reason = reason
        ticket.latency_s = self._clock() - ticket.submit_t
        ticket._event.set()
        return True

    @property
    def queue_depth(self) -> int:
        """Current admitted-but-unserved requests (router load signal)."""
        with self._lock:
            return len(self._queue)

    # ------------------------------------------------------------------ serve

    def pump(self, block: bool = False, timeout: float | None = None) -> int:
        """Form and serve one micro-batch.  Returns completed requests."""
        cfg = self.config
        taken: list[SpatialTicket] = []
        with self._not_empty:
            if block and not self._queue:
                self._not_empty.wait(timeout)
            while self._queue and len(taken) < cfg.batch_size:
                taken.append(self._queue.popleft())
            self._queue_gauge.set(len(self._queue))
        if not taken:
            return 0

        with obs_trace.span("serve.form_batch", phase=obs_phases.HOST,
                            taken=len(taken)):
            now = self._clock()
            live: list[SpatialTicket] = []
            for t in taken:
                if t.deadline < now:
                    self._events.inc(kind="expired")
                    t.status = STATUS_EXPIRED
                    t.latency_s = now - t.submit_t
                    t._event.set()
                else:
                    live.append(t)
            if not live:
                return len(taken)

            for t in live:
                self._wait_hist.observe(now - t.submit_t)
            k = len(live)
            batch = np.stack([t.rect for t in live]).astype(np.int32)
            inv = None
            if cfg.sort_batches and k > 1:
                order = morton_order(batch)
                inv = np.argsort(order, kind="stable")
                batch = batch[order]
            pad = cfg.batch_size - k
            if pad:
                batch = np.concatenate(
                    [batch, np.tile(self._pad_rect, (pad, 1))])

        t0 = self._clock()
        counts, path = self._execute(batch, k)
        dt = self._clock() - t0
        if inv is not None:
            counts = counts[inv]

        done_t = self._clock()
        self._batch_hist.observe(dt)
        self._events.inc(k, kind="served")
        with self._lock:
            self._batch_ewma_s = (dt if self._batch_ewma_s is None
                                  else 0.8 * self._batch_ewma_s + 0.2 * dt)
            self._served_batches += 1
        for t, c in zip(live, counts):
            t.status = STATUS_OK
            t.count = int(c)
            t.path = path
            t.latency_s = done_t - t.submit_t
            self._req_hist.observe(t.latency_s)
            t._event.set()
        return len(taken)

    def drain(self, timeout: float = 30.0) -> int:
        """Pump until the queue is empty (bounded by ``timeout``)."""
        served = 0
        deadline = self._clock() + timeout
        while self._queue and self._clock() < deadline:
            served += self.pump()
        return served

    # --------------------------------------------------------------- execute

    def _execute(self, padded: np.ndarray, k: int
                 ) -> tuple[np.ndarray, str]:
        """Serve one padded batch: fast path with watchdog/retry/cross-check,
        degrading to (and recovering from) the reference path."""
        cfg = self.config
        if self.health == DEGRADED:
            self._degraded_batches_since += 1
            if (cfg.probe_every > 0
                    and self._degraded_batches_since % cfg.probe_every == 0):
                counts = self._probe(padded, k)
                if counts is not None:
                    return counts[:k], PATH_FAST
            self._events.inc(kind="degraded_batches")
            return self._ref_counts(padded[:k]), PATH_REF

        last: Exception | None = None
        for attempt in range(cfg.max_retries + 1):
            try:
                counts = self._fast_batch(padded)
                self._maybe_crosscheck(padded, counts, k)
                return counts[:k], PATH_FAST
            except Exception as e:          # bounded: max_retries + 1 attempts
                last = e
                self._record_fault(e)
                if attempt < cfg.max_retries:
                    self._sleep(min(cfg.backoff_base_s * (2 ** attempt),
                                    cfg.backoff_cap_s))
        self._degrade(last)
        self._events.inc(kind="degraded_batches")
        return self._ref_counts(padded[:k]), PATH_REF

    def _fast_batch(self, padded: np.ndarray) -> np.ndarray:
        """One watchdog-guarded fast-path attempt: stage → step → retrieve.

        The stage/step/retrieve spans open on the guarded *worker* thread,
        so their self-times parent under that thread's ``serve.batch`` span;
        the pump thread deliberately does not wrap its wait on the future —
        that would double-count the same wall time from a second thread."""

        def call():
            with obs_trace.span("serve.batch", phase=obs_phases.HOST,
                                batch_size=int(padded.shape[0])):
                with obs_trace.span("serve.stage", phase=obs_phases.H2D):
                    staged = self._place(padded, self._rep_sh)
                with warnings.catch_warnings():
                    # Same expected advisory as stream_batches: the donated
                    # (bs, 4) query buffer can never alias the (bs,) counts.
                    warnings.filterwarnings(
                        "ignore",
                        message="Some donated buffers were not usable")
                    with obs_trace.span("serve.step",
                                        phase=obs_phases.KERNEL):
                        out = self._step(*self._operands, staged)
                        if obs_trace.enabled():
                            # only when tracing: charge device time to the
                            # kernel span instead of the retrieve below
                            jax.block_until_ready(out)  # pallint: disable=PL102
                with obs_trace.span("serve.retrieve", phase=obs_phases.D2H):
                    return np.asarray(jax.device_get(out))

        # One daemon thread per guarded attempt (not a ThreadPoolExecutor):
        # pool workers are non-daemon and joined at interpreter exit, so a
        # step call that never returns — the exact failure the watchdog
        # exists for — would block process shutdown forever after being
        # "abandoned" here.  A hung daemon thread dies with the process.
        fut: concurrent.futures.Future = concurrent.futures.Future()

        def runner():
            try:
                fut.set_result(call())
            except BaseException as e:
                fut.set_exception(e)

        threading.Thread(target=runner, name="serve-step",
                         daemon=True).start()
        try:
            counts = fut.result(timeout=self.config.watchdog_s)
        except concurrent.futures.TimeoutError:
            # Abandon the stuck worker (it finishes or dies on its own);
            # the next attempt gets a fresh one — never wait on a straggler.
            obs_trace.event("serve.watchdog_timeout",
                            budget_s=self.config.watchdog_s)
            raise WatchdogTimeout(
                f"batch exceeded watchdog {self.config.watchdog_s}s") from None
        self._sanity_check(counts, padded.shape[0])
        return counts

    def _sanity_check(self, counts: np.ndarray, bs: int) -> None:
        """Cheap full-batch output validation: shape, dtype, count bounds.
        Catches NaN/corrupted kernel output before any response is released."""
        n = self._host_rects.shape[0]
        if counts.shape != (bs,):
            raise CorruptOutputError(
                f"fast path returned shape {counts.shape}, expected ({bs},)")
        if counts.dtype.kind not in "iu":
            raise CorruptOutputError(
                f"fast path returned dtype {counts.dtype}, expected integer")
        if counts.size and (int(counts.min()) < 0 or int(counts.max()) > n):
            raise CorruptOutputError(
                "fast path returned counts outside [0, num_rects]")

    def _maybe_crosscheck(self, padded: np.ndarray, counts: np.ndarray,
                          k: int) -> None:
        """Healthy-state sampled oracle cross-check (silent-corruption net)."""
        cfg = self.config
        if cfg.crosscheck_every <= 0:
            return
        if self._served_batches % cfg.crosscheck_every != 0:
            return
        m = min(k, cfg.crosscheck_samples)
        if m == 0:
            return
        self._events.inc(kind="crosschecks")
        want = ref.overlap_counts_np_chunked(padded[:m], self._host_rects)
        if not np.array_equal(counts[:m].astype(np.int32), want):
            raise CorruptOutputError(
                "sampled cross-check mismatch against the reference kernel")

    def _probe(self, padded: np.ndarray, k: int) -> np.ndarray | None:
        """Degraded-state recovery probe: one guarded fast-path attempt,
        validated against the reference on a sample before trusting it."""
        self._events.inc(kind="probes")
        try:
            counts = self._fast_batch(padded)
            m = min(k, max(self.config.crosscheck_samples, 1))
            want = ref.overlap_counts_np_chunked(
                padded[:m], self._host_rects)
            if not np.array_equal(counts[:m].astype(np.int32), want):
                raise CorruptOutputError("probe cross-check mismatch")
        except Exception as e:              # probe failed; stay degraded
            self._record_fault(e)
            return None
        with self._lock:
            self.health = HEALTHY
            self._degraded_batches_since = 0
        self._events.inc(kind="recoveries")
        self._health_gauge.set(1.0)
        obs_trace.event("serve.recover")
        return counts

    def _ref_counts(self, queries: np.ndarray) -> np.ndarray:
        """The degradation path: exact counts from the host rect copy."""
        return ref.overlap_counts_np_chunked(queries, self._host_rects)

    def _record_fault(self, e: Exception) -> None:
        kind = ("watchdog" if isinstance(e, WatchdogTimeout)
                else "corrupt" if isinstance(e, CorruptOutputError)
                else type(e).__name__)
        self._events.inc(kind="retries")
        self._fault_counter.inc(kind=kind)
        obs_trace.event("serve.retry", kind=kind)
        with self._lock:
            self._last_fault = f"{kind}: {e}"

    def _degrade(self, e: Exception | None) -> None:
        with self._lock:
            degraded_now = self.health != DEGRADED
            if degraded_now:
                self.health = DEGRADED
                self._degraded_batches_since = 0
        if degraded_now:
            self._events.inc(kind="degradations")
            self._health_gauge.set(0.0)
            obs_trace.event("serve.degrade",
                            reason=type(e).__name__ if e else "unknown")

    def _warmup(self, bs: int) -> None:
        """Compile the (bs, 4) step once, outside the watchdog — compilation
        time must never be mistaken for a straggler."""
        padded = np.tile(self._pad_rect, (bs, 1))
        staged = self._place(padded, self._rep_sh)
        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            np.asarray(jax.device_get(self._step(*self._operands, staged)))

    # ------------------------------------------------------------- lifecycle

    def start(self) -> None:
        """Run the serving loop on a background worker thread."""
        if self._thread is not None:
            return
        self._stop_evt.clear()
        self._thread = threading.Thread(
            target=self._run, name="spatial-serve", daemon=True)
        self._thread.start()

    def _run(self) -> None:
        while not self._stop_evt.is_set():
            self.pump(block=True, timeout=0.05)

    def stop(self, drain: bool = True, timeout: float = 30.0) -> None:
        """Stop the worker; optionally drain the queue first (bounded)."""
        with self._lock:
            self._accepting = False
        if self._thread is not None:
            self._stop_evt.set()
            with self._not_empty:
                self._not_empty.notify_all()
            self._thread.join(timeout)
            self._thread = None
        if drain:
            self.drain(timeout)

    # --------------------------------------------------------------- observe

    def metrics(self) -> dict:
        """Snapshot of the health/metrics surface.

        A view over ``self.registry`` keeping the original dict shape:
        counts come from the ``serve_events_total``/``serve_faults_total``
        counter families, and latency percentiles are interpolated estimates
        from the shared fixed-bucket histograms (cumulative since server
        construction — see :class:`repro.obs.metrics.Histogram`) instead of
        a re-sorted ring per call."""
        with self._lock:
            depth = len(self._queue)
            health = self.health
            last_fault = self._last_fault
        c = {k: int(v) for k, v in self._events.as_dict("kind").items()}
        faults = {k: int(v)
                  for k, v in self._fault_counter.as_dict("kind").items()}
        submitted = c.get("submitted", 0)
        shed = sum(v for k, v in c.items() if k.startswith("shed_"))
        return {
            "health": health,
            "queue_depth": depth,
            "submitted": submitted,
            "served": c.get("served", 0),
            "shed": shed,
            "shed_rate": shed / submitted if submitted else 0.0,
            "expired": c.get("expired", 0),
            "retries": c.get("retries", 0),
            "degradations": c.get("degradations", 0),
            "degraded_batches": c.get("degraded_batches", 0),
            "recoveries": c.get("recoveries", 0),
            "probes": c.get("probes", 0),
            "crosschecks": c.get("crosschecks", 0),
            "faults": faults,
            "last_fault": last_fault,
            "batch_p50_s": self._batch_hist.percentile(50),
            "batch_p90_s": self._batch_hist.percentile(90),
            "batch_p99_s": self._batch_hist.percentile(99),
            "request_p50_s": self._req_hist.percentile(50),
            "request_p90_s": self._req_hist.percentile(90),
            "request_p99_s": self._req_hist.percentile(99),
            "queue_wait_p50_s": self._wait_hist.percentile(50),
            "queue_wait_p99_s": self._wait_hist.percentile(99),
            "counters": c,
        }
