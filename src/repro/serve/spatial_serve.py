"""Fault-tolerant always-on spatial serving (DESIGN.md Sec 11).

Everything in :mod:`repro.core.engine` assumes a perfect offline run: one
``query(all_queries)`` call, no request queue, no deadlines, no recovery
from a lost device or a corrupted kernel output.  This module is the bridge
to the ROADMAP's "millions of users" serving layer, with robustness as the
headline:

* **Bounded request queue + admission control** — :meth:`SpatialServer.submit`
  validates each rect (strict mode: malformed requests are refused, never
  reinterpreted), sheds explicitly when the queue is full, and sheds at
  admission when the EWMA batch latency predicts the deadline cannot be met
  (backpressure as an explicit signal, not an unbounded queue).
* **Micro-batch formation into the one compiled shape** — requests are
  drained into ``(batch_size, 4)`` batches, Morton-ordered per batch for
  tile-MBR locality (counts are un-permuted on completion), EMPTY-padded to
  the fixed shape so the jitted step never retraces.
* **Watchdog + capped exponential backoff** — each fast-path batch runs
  under a watchdog timeout (PrIM shows wide per-DPU latency variance;
  stragglers are the norm, not the exception); failures retry a *bounded*
  number of times with capped backoff (pallint PL110 machine-checks that
  serving loops stay bounded).
* **Graceful degradation** — after retries are exhausted (device loss,
  persistent stragglers, corrupted output) the server degrades to the exact
  NumPy reference kernel (:func:`repro.kernels.ref.overlap_counts_np_chunked`)
  over the host copy of the leaf rects, and probes the fast path periodically
  to recover.  In healthy steady state a sampled oracle cross-check guards
  against silent corruption; a failed cross-check is treated as a fault.
* **Health/metrics surface** — queue depth, shed/expired counts, retries,
  degradations/recoveries, per-fault counters, and p50/p90/p99 batch and
  request latency.  All of it is backed by a per-server
  :class:`repro.obs.metrics.Registry` (``server.registry``): events and
  faults are labeled counter families (``serve_events_total{kind=...}``,
  ``serve_faults_total{kind=...}``), latencies are fixed-bucket histograms
  (``serve_batch_latency_seconds``, ``serve_request_latency_seconds``,
  ``serve_queue_wait_seconds``) whose percentiles are interpolated estimates
  over *all observations since server construction* (cumulative window,
  Prometheus semantics — not a sliding ring).  :meth:`SpatialServer.metrics`
  keeps its original dict shape on top of the registry, and
  ``server.registry.prometheus_text()`` exports the same numbers for
  scraping.  Batches and fault-handling transitions also emit spans/events
  into the :mod:`repro.obs.trace` tracer when it is enabled (DESIGN.md
  Sec 12).

* **Query kinds** — ``submit(..., kind=...)`` accepts every
  :data:`SERVE_KINDS` request (count / ids / knn / radius / aggregate) with
  strict per-kind admission validation.  Each kind gets its own queue and
  its own single-kind micro-batches (one compiled shape per kind; batches
  are formed FIFO by oldest queue head), its own lazily compiled step from
  the engine's :meth:`repro.core.engine.QueryKindMixin.kind_step` cache,
  per-kind sanity checks and oracle cross-checks
  (:mod:`repro.query.oracle`), and a per-kind degradation path.  Admitted
  requests are counted per kind in ``serve_queries_total{query_kind=...}``.

Fault injection for all of the above lives in :mod:`repro.testing.chaos`,
which wraps the two seams this module exposes (``_step`` — the jitted query
step, and ``_place`` — batch staging via ``jax.device_put``).

In no-fault steady state the served counts are bit-equal to
``BroadcastEngine.query``: same step, same padding, same Morton ordering.
"""
from __future__ import annotations

import collections
import concurrent.futures
import dataclasses
import threading
import time
import warnings
from typing import Callable

import numpy as np

import jax

from repro.core.engine import (
    EMPTY_RECT, QueryValidationError, morton_order, validate_k,
    validate_queries, validate_radii)
from repro.kernels import ref
from repro.obs import metrics as obs_metrics
from repro.obs import phases as obs_phases
from repro.obs import trace as obs_trace
from repro.query import oracle as qoracle
from repro.query import pipelines as qp

HEALTHY = "healthy"
DEGRADED = "degraded"

# every admissible request kind: the count fast path plus the materializing
# kinds of repro.query (DESIGN.md Sec 14)
SERVE_KINDS = ("count",) + qp.QUERY_KINDS

STATUS_PENDING = "pending"
STATUS_OK = "ok"
STATUS_SHED = "shed"
STATUS_EXPIRED = "expired"
STATUS_CANCELLED = "cancelled"

PATH_FAST = "fast"
PATH_REF = "ref"


class WatchdogTimeout(RuntimeError):
    """The fast-path batch exceeded the watchdog deadline (straggler)."""


class CorruptOutputError(RuntimeError):
    """The fast path returned counts that failed sanity or cross-check."""


class SpatialTicket:
    """One submitted request: completion event + result fields.

    ``status`` is one of ``ok`` / ``shed`` / ``expired`` / ``cancelled``
    (or ``pending`` until completed); ``path`` records which execution path
    answered (``fast`` or ``ref``), ``reason`` why a request was shed or
    cancelled.  ``kind`` selects the query kind; ``rect`` holds the packed
    ``(4,)`` payload row (the rect itself for count/ids/aggregate,
    ``[x, y, 0, 0]`` for knn, ``[x, y, r, 0]`` for radius).  ``count`` is
    filled for every kind; ``ids``/``distances``/``overflow``/``aggregates``
    only where the kind produces them (see
    :class:`repro.query.SpatialResult`)."""

    __slots__ = ("rect", "kind", "submit_t", "deadline", "status", "reason",
                 "count", "ids", "distances", "overflow", "aggregates",
                 "path", "latency_s", "_event")

    def __init__(self, rect: np.ndarray, submit_t: float, deadline: float,
                 kind: str = "count"):
        self.rect = rect
        self.kind = kind
        self.submit_t = submit_t
        self.deadline = deadline
        self.status = STATUS_PENDING
        self.reason = None
        self.count = None
        self.ids = None
        self.distances = None
        self.overflow = None
        self.aggregates = None
        self.path = None
        self.latency_s = None
        self._event = threading.Event()

    @property
    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: float | None = None) -> bool:
        """Block until completed; returns False on wait timeout."""
        return self._event.wait(timeout)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Serving-loop policy knobs (every bound the chaos suite exercises)."""

    batch_size: int = 256           # the one compiled (bs, 4) shape
    max_queue: int = 1024           # bounded queue; beyond this, shed
    default_deadline_s: float = 1.0
    watchdog_s: float = 2.0         # per-attempt fast-path time budget
    max_retries: int = 3            # bounded retry (PL110 doctrine)
    backoff_base_s: float = 0.02    # capped exponential backoff
    backoff_cap_s: float = 0.5
    crosscheck_every: int = 64      # healthy-state sampled oracle check
    crosscheck_samples: int = 8
    probe_every: int = 8            # degraded-state fast-path probe cadence
    sort_batches: bool = True       # per-batch Morton ordering
    # query-kind parameters: one compiled shape per kind, so the per-request
    # knobs (k, kcap) are server-wide policy, validated at construction
    knn_k: int = 8
    kcap: int = qp.DEFAULT_KCAP


def pack_request(query, kind: str, radius=None, *,
                 where: str = "submit") -> np.ndarray:
    """Strict per-kind admission validation → one packed (4,) payload row.

    Malformed requests are refused, never reinterpreted: unknown kinds, a
    radius on a non-radius kind, a missing/NaN/negative radius, and
    wrong-shape queries (rect where a point is expected or vice versa) all
    raise :class:`repro.core.engine.QueryValidationError`.  Shared by the
    server and the router so both admission boundaries enforce the same
    contract."""
    if kind not in SERVE_KINDS:
        raise QueryValidationError(
            f"{where}: unknown query kind (expected one of {SERVE_KINDS})")
    if kind in ("knn", "radius"):
        arr = np.asarray(query)
        if arr.shape == (2,):
            arr = arr.reshape(1, 2)
        pt = validate_queries(arr, points=True, strict=True, where=where)
        if kind == "knn":
            if radius is not None:
                raise QueryValidationError(
                    f"{where}: radius is not a knn parameter")
            return qp.pack_knn(pt)[0]
        if radius is None:
            raise QueryValidationError(
                f"{where}: radius kind requires a radius")
        rad = validate_radii(np.asarray([radius]), where=where)
        return qp.pack_radius(pt, rad)[0]
    if radius is not None:
        raise QueryValidationError(
            f"{where}: radius only applies to the radius kind")
    arr = np.asarray(query)
    if arr.shape == (4,):
        arr = arr.reshape(1, 4)
    return validate_queries(arr, strict=True, where=where)[0]


def _engine_bindings(engine):
    """Extract (step, operands, rep_sharding, host_rects) from an engine.

    Works for both ``BroadcastEngine`` and ``SubtreeEngine`` — the step
    arity and the replicated query sharding are identical; only the operand
    names and the host-side rect layout differ."""
    if hasattr(engine, "leaf_coords"):          # BroadcastEngine
        operands = (engine.leaf_coords, engine.rect_tile_mbrs,
                    engine.cover_mbrs)
        flat = engine.layout.leaf_rects_flat
    else:                                       # SubtreeEngine
        operands = (engine.dev_coords, engine.dev_tile_mbrs, engine.dev_mbrs)
        flat = engine.layout.rects.reshape(-1, 4)
    host_rects = flat[flat[:, 0] <= flat[:, 2]]
    return engine._step, operands, engine._rep_sh, host_rects


class SpatialServer:
    """Always-on serving loop over a spatial engine's compiled query step.

    Single-consumer: ``pump``/``drain`` must be driven from one thread
    (either the caller's, or the background worker started by
    :meth:`start`).  ``submit`` is thread-safe.
    """

    def __init__(
        self,
        engine,
        config: ServeConfig | None = None,
        *,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
        warmup: bool = True,
        registry: obs_metrics.Registry | None = None,
    ):
        self.engine = engine
        self.config = config or ServeConfig()
        self._clock = clock
        self._sleep = sleep

        # the two chaos seams: the jitted step and batch staging
        self._step, self._operands, self._rep_sh, self._host_rects = (
            _engine_bindings(engine))
        self._place = jax.device_put

        # query-kind surface: lazily compiled steps (shared with the
        # engine's own cache) + host placed arrays for the kind oracles
        validate_k(self.config.knn_k, where="ServeConfig.knn_k")
        validate_k(self.config.kcap, where="ServeConfig.kcap")
        self._kind_supported = hasattr(engine, "kind_step")
        self._placed_rects = getattr(engine, "placed_rects", None)
        self._placed_ids = getattr(engine, "placed_ids", None)
        self._max_id = (int(self._placed_ids.max())
                        if self._placed_ids is not None
                        and self._placed_ids.size else -1)
        self._warm_kinds: set[str] = {"count"}
        self._pad_rows = dict(qp.PAD_ROWS)
        self._pad_rows["count"] = np.asarray(
            EMPTY_RECT, dtype=np.int32).reshape(4)

        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._queues: dict[str, collections.deque[SpatialTicket]] = {
            k: collections.deque() for k in SERVE_KINDS}
        self._accepting = True
        self._stop_evt = threading.Event()
        self._thread: threading.Thread | None = None

        self.health = HEALTHY
        self._served_batches = 0
        self._degraded_batches_since = 0
        self._batch_ewma_s: float | None = None
        self._last_fault: str | None = None

        # registry-backed metrics surface (per-server by default, so two
        # servers never share series; pass a registry to aggregate)
        self.registry = registry if registry is not None else (
            obs_metrics.Registry())
        self._events = self.registry.counter(
            "serve_events_total",
            "serving-loop events by kind (submitted/served/shed_*/...)")
        self._kind_counter = self.registry.counter(
            "serve_queries_total",
            "admitted requests by query kind (count/ids/knn/...)")
        self._fault_counter = self.registry.counter(
            "serve_faults_total", "fast-path faults by kind")
        self._health_gauge = self.registry.gauge(
            "serve_healthy", "1 while on the fast path, 0 while degraded")
        self._health_gauge.set(1.0)
        self._queue_gauge = self.registry.gauge(
            "serve_queue_depth", "current admitted-but-unserved requests")
        self._batch_hist = self.registry.histogram(
            "serve_batch_latency_seconds",
            "wall time of one served micro-batch (execute only)")
        self._req_hist = self.registry.histogram(
            "serve_request_latency_seconds",
            "submit-to-completion latency of served requests")
        self._wait_hist = self.registry.histogram(
            "serve_queue_wait_seconds",
            "submit-to-batch-formation wait of served requests")

        bs = self.config.batch_size
        self._pad_rect = np.asarray(EMPTY_RECT, dtype=np.int32).reshape(1, 4)
        if warmup:
            self._warmup(bs)

    # ------------------------------------------------------------------ admit

    def _pack_request(self, query, kind: str, radius) -> np.ndarray:
        where = f"SpatialServer.submit[{kind}]"
        if kind != "count" and not self._kind_supported:
            raise QueryValidationError(
                f"{where}: engine has no query-kind surface")
        return pack_request(query, kind, radius, where=where)

    def submit(self, rect, *, kind: str = "count", radius=None,
               deadline_s: float | None = None) -> SpatialTicket:
        """Admit one request.  Always returns a ticket; a shed request comes
        back already completed with ``status='shed'``.

        ``kind`` selects the query kind: ``count`` (default, a rect),
        ``ids``/``aggregate`` (a rect), ``knn`` (an ``[x, y]`` point), or
        ``radius`` (a point plus ``radius=``).  Per-request ``k``/``kcap``
        would retrace the one compiled shape, so they are server policy
        (:class:`ServeConfig.knn_k` / ``kcap``), not submit parameters."""
        payload = self._pack_request(rect, kind, radius)
        now = self._clock()
        if deadline_s is None:
            deadline_s = self.config.default_deadline_s
        ticket = SpatialTicket(payload, now, now + deadline_s, kind=kind)
        self._events.inc(kind="submitted")
        self._kind_counter.inc(query_kind=kind)
        if deadline_s <= 0:
            # Already expired at submit: shed immediately instead of letting
            # a dead request occupy a batch slot until pump() notices.
            return self._shed(ticket, "deadline", now)
        with self._lock:
            if not self._accepting:
                return self._shed(ticket, "stopped", now)
            depth = sum(len(q) for q in self._queues.values())
            if depth >= self.config.max_queue:
                return self._shed(ticket, "capacity", now)
            ewma = self._batch_ewma_s
            if ewma is not None:
                batches_ahead = depth // self.config.batch_size + 1
                if now + batches_ahead * ewma > ticket.deadline:
                    return self._shed(ticket, "deadline", now)
            self._queues[kind].append(ticket)
            self._queue_gauge.set(depth + 1)
            self._not_empty.notify()
        return ticket

    def _shed(self, ticket: SpatialTicket, reason: str, now: float
              ) -> SpatialTicket:
        self._events.inc(kind=f"shed_{reason}")
        obs_trace.event("serve.shed", reason=reason)
        ticket.status = STATUS_SHED
        ticket.reason = reason
        ticket.latency_s = now - ticket.submit_t
        ticket._event.set()
        return ticket

    def cancel(self, ticket: SpatialTicket, reason: str = "cancelled") -> bool:
        """Withdraw a still-queued request (e.g. a hedged duplicate whose
        twin already answered).  Returns True iff the ticket was removed
        before batch formation; a ticket already being served (or done)
        cannot be cancelled and keeps its eventual result."""
        with self._lock:
            try:
                self._queues[ticket.kind].remove(ticket)
            except ValueError:
                return False
            self._queue_gauge.set(
                sum(len(q) for q in self._queues.values()))
        self._events.inc(kind="cancelled")
        obs_trace.event("serve.cancel", reason=reason)
        ticket.status = STATUS_CANCELLED
        ticket.reason = reason
        ticket.latency_s = self._clock() - ticket.submit_t
        ticket._event.set()
        return True

    @property
    def queue_depth(self) -> int:
        """Current admitted-but-unserved requests (router load signal)."""
        with self._lock:
            return sum(len(q) for q in self._queues.values())

    # ------------------------------------------------------------------ serve

    def _next_kind(self) -> str | None:
        """The kind whose queue head has waited longest (FIFO fairness at
        batch granularity; each micro-batch is single-kind because each kind
        has its own compiled shape)."""
        best = None
        for kind, q in self._queues.items():
            if q and (best is None or q[0].submit_t < best[1]):
                best = (kind, q[0].submit_t)
        return best[0] if best else None

    def pump(self, block: bool = False, timeout: float | None = None) -> int:
        """Form and serve one single-kind micro-batch.  Returns completed
        requests."""
        cfg = self.config
        taken: list[SpatialTicket] = []
        with self._not_empty:
            if block and not any(self._queues.values()):
                self._not_empty.wait(timeout)
            kind = self._next_kind()
            if kind is not None:
                q = self._queues[kind]
                while q and len(taken) < cfg.batch_size:
                    taken.append(q.popleft())
            self._queue_gauge.set(
                sum(len(q) for q in self._queues.values()))
        if not taken:
            return 0

        with obs_trace.span("serve.form_batch", phase=obs_phases.HOST,
                            taken=len(taken), query_kind=kind):
            now = self._clock()
            live: list[SpatialTicket] = []
            for t in taken:
                if t.deadline < now:
                    self._events.inc(kind="expired")
                    t.status = STATUS_EXPIRED
                    t.latency_s = now - t.submit_t
                    t._event.set()
                else:
                    live.append(t)
            if not live:
                return len(taken)

            for t in live:
                self._wait_hist.observe(now - t.submit_t)
            k = len(live)
            batch = np.stack([t.rect for t in live]).astype(np.int32)
            inv = None
            if cfg.sort_batches and k > 1:
                rect_view = (batch if kind == "count"
                             else qp.payload_rects(kind, batch))
                order = morton_order(rect_view)
                inv = np.argsort(order, kind="stable")
                batch = batch[order]
            pad = cfg.batch_size - k
            if pad:
                batch = np.concatenate(
                    [batch,
                     np.tile(self._pad_rows[kind].reshape(1, 4), (pad, 1))])

        t0 = self._clock()
        out, path = self._execute(batch, k, kind)
        dt = self._clock() - t0
        if inv is not None:
            out = jax.tree_util.tree_map(lambda x: x[inv], out)

        done_t = self._clock()
        self._batch_hist.observe(dt)
        self._events.inc(k, kind="served")
        with self._lock:
            self._batch_ewma_s = (dt if self._batch_ewma_s is None
                                  else 0.8 * self._batch_ewma_s + 0.2 * dt)
            self._served_batches += 1
        self._complete_live(live, out, kind, path, done_t)
        return len(taken)

    def _complete_live(self, live, out, kind, path, done_t) -> None:
        """Release per-request results from the batch output."""
        if kind == "count":
            results = [{"count": int(c)} for c in out]
        else:
            res = qp.assemble(kind, out, kcap=self._kind_param(kind) or 0)
            results = []
            for i in range(len(live)):
                fields = {"count": int(res.count[i])}
                if res.ids is not None:
                    fields["ids"] = res.ids[i]
                if res.distances is not None:
                    fields["distances"] = res.distances[i]
                if res.overflow is not None:
                    fields["overflow"] = int(res.overflow[i])
                if res.aggregates is not None:
                    fields["aggregates"] = {
                        "sums": res.aggregates["sums"][i],
                        "bbox": res.aggregates["bbox"][i]}
                results.append(fields)
        for t, fields in zip(live, results):
            t.status = STATUS_OK
            for name, value in fields.items():
                setattr(t, name, value)
            t.path = path
            t.latency_s = done_t - t.submit_t
            self._req_hist.observe(t.latency_s)
            t._event.set()

    def drain(self, timeout: float = 30.0) -> int:
        """Pump until the queue is empty (bounded by ``timeout``)."""
        served = 0
        deadline = self._clock() + timeout
        while any(self._queues.values()) and self._clock() < deadline:
            served += self.pump()
        return served

    # --------------------------------------------------------------- execute

    def _kind_param(self, kind: str) -> int | None:
        """The compiled-shape parameter of a kind (k or kcap)."""
        if kind in ("ids", "radius"):
            return self.config.kcap
        if kind == "knn":
            return self.config.knn_k
        return None

    def _step_for(self, kind: str):
        """The jitted step serving ``kind`` — the count path keeps the
        ``_step`` chaos seam; the query kinds share the engine's lazily
        compiled per-(kind, param) cache."""
        if kind == "count":
            return self._step
        return self.engine.kind_step(kind, self._kind_param(kind))

    def _warm_kind(self, kind: str, bs: int) -> None:
        """First-use compilation of a kind step, outside the watchdog (and
        outside the chaos seams — compilation is not the serving path)."""
        if kind in self._warm_kinds:
            return
        padded = np.tile(self._pad_rows[kind].reshape(1, 4), (bs, 1))
        staged = jax.device_put(padded, self._rep_sh)
        step = self._step_for(kind)
        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            jax.device_get(step(*self.engine._kind_operands(), staged))
        self._warm_kinds.add(kind)

    def _execute(self, padded: np.ndarray, k: int, kind: str = "count"
                 ) -> tuple:
        """Serve one padded batch: fast path with watchdog/retry/cross-check,
        degrading to (and recovering from) the reference path."""
        cfg = self.config
        if self.health == DEGRADED:
            self._degraded_batches_since += 1
            if (cfg.probe_every > 0
                    and self._degraded_batches_since % cfg.probe_every == 0):
                out = self._probe(padded, k, kind)
                if out is not None:
                    return self._slice_out(out, k, kind), PATH_FAST
            self._events.inc(kind="degraded_batches")
            return self._ref_answer(padded[:k], kind), PATH_REF

        last: Exception | None = None
        try:
            self._warm_kind(kind, padded.shape[0])
        except Exception as e:      # compile failure: fast path is broken
            self._record_fault(e)
            self._degrade(e)
            self._events.inc(kind="degraded_batches")
            return self._ref_answer(padded[:k], kind), PATH_REF
        for attempt in range(cfg.max_retries + 1):
            try:
                out = self._fast_batch(padded, kind)
                self._maybe_crosscheck(padded, out, k, kind)
                return self._slice_out(out, k, kind), PATH_FAST
            except Exception as e:          # bounded: max_retries + 1 attempts
                last = e
                self._record_fault(e)
                if attempt < cfg.max_retries:
                    self._sleep(min(cfg.backoff_base_s * (2 ** attempt),
                                    cfg.backoff_cap_s))
        self._degrade(last)
        self._events.inc(kind="degraded_batches")
        return self._ref_answer(padded[:k], kind), PATH_REF

    @staticmethod
    def _slice_out(out, k: int, kind: str):
        if kind == "count":
            return out[:k]
        return tuple(x[:k] for x in out)

    def _fast_batch(self, padded: np.ndarray, kind: str = "count"):
        """One watchdog-guarded fast-path attempt: stage → step → retrieve.

        The stage/step/retrieve spans open on the guarded *worker* thread,
        so their self-times parent under that thread's ``serve.batch`` span;
        the pump thread deliberately does not wrap its wait on the future —
        that would double-count the same wall time from a second thread."""
        step = self._step_for(kind)
        operands = (self._operands if kind == "count"
                    else self.engine._kind_operands())

        def call():
            with obs_trace.span("serve.batch", phase=obs_phases.HOST,
                                batch_size=int(padded.shape[0]),
                                query_kind=kind):
                with obs_trace.span("serve.stage", phase=obs_phases.H2D):
                    staged = self._place(padded, self._rep_sh)
                with warnings.catch_warnings():
                    # Same expected advisory as stream_batches: the donated
                    # (bs, 4) query buffer can never alias the (bs,) counts.
                    warnings.filterwarnings(
                        "ignore",
                        message="Some donated buffers were not usable")
                    with obs_trace.span("serve.step",
                                        phase=obs_phases.KERNEL):
                        out = step(*operands, staged)
                        if obs_trace.enabled():
                            # only when tracing: charge device time to the
                            # kernel span instead of the retrieve below
                            jax.block_until_ready(out)  # pallint: disable=PL102
                with obs_trace.span("serve.retrieve", phase=obs_phases.D2H):
                    if kind == "count":
                        return np.asarray(jax.device_get(out))
                    return tuple(
                        np.asarray(x) for x in jax.device_get(out))

        # One daemon thread per guarded attempt (not a ThreadPoolExecutor):
        # pool workers are non-daemon and joined at interpreter exit, so a
        # step call that never returns — the exact failure the watchdog
        # exists for — would block process shutdown forever after being
        # "abandoned" here.  A hung daemon thread dies with the process.
        fut: concurrent.futures.Future = concurrent.futures.Future()

        def runner():
            try:
                fut.set_result(call())
            except BaseException as e:
                fut.set_exception(e)

        threading.Thread(target=runner, name="serve-step",
                         daemon=True).start()
        try:
            out = fut.result(timeout=self.config.watchdog_s)
        except concurrent.futures.TimeoutError:
            # Abandon the stuck worker (it finishes or dies on its own);
            # the next attempt gets a fresh one — never wait on a straggler.
            obs_trace.event("serve.watchdog_timeout",
                            budget_s=self.config.watchdog_s)
            raise WatchdogTimeout(
                f"batch exceeded watchdog {self.config.watchdog_s}s") from None
        self._sanity_check(out, padded.shape[0], kind)
        return out

    def _sanity_check(self, out, bs: int, kind: str = "count") -> None:
        """Cheap full-batch output validation: shape, dtype, value bounds.
        Catches NaN/corrupted kernel output before any response is released."""
        n = self._host_rects.shape[0]

        def counts_ok(counts, what):
            if counts.shape != (bs,):
                raise CorruptOutputError(
                    f"fast path returned {what} shape {counts.shape}, "
                    f"expected ({bs},)")
            if counts.dtype.kind not in "iu":
                raise CorruptOutputError(
                    f"fast path returned {what} dtype {counts.dtype}, "
                    "expected integer")
            if counts.size and (int(counts.min()) < 0
                                or int(counts.max()) > n):
                raise CorruptOutputError(
                    f"fast path returned {what} outside [0, num_rects]")

        if kind == "count":
            counts_ok(out, "counts")
            return
        if kind in ("ids", "radius"):
            slots, counts = out
            counts_ok(counts, "totals")
            kcap = self.config.kcap
            if slots.shape != (bs, kcap) or slots.dtype.kind not in "iu":
                raise CorruptOutputError(
                    f"fast path returned slots {slots.shape} {slots.dtype}")
            if slots.size and (int(slots.min()) < 0
                               or int(slots.max()) > self._max_id + 1):
                raise CorruptOutputError(
                    "fast path returned IDs outside the placed range")
            return
        if kind == "knn":
            dists, ids = out
            kk = self.config.knn_k
            if dists.shape != (bs, kk) or ids.shape != (bs, kk):
                raise CorruptOutputError(
                    f"fast path returned knn shapes {dists.shape}/{ids.shape}")
            if dists.size and (np.isnan(dists).any()
                               or float(np.nanmin(dists)) < 0.0):
                raise CorruptOutputError(
                    "fast path returned NaN/negative knn distances")
            if ids.size and (int(ids.min()) < -1
                             or int(ids.max()) > self._max_id):
                raise CorruptOutputError(
                    "fast path returned knn IDs outside the placed range")
            return
        counts, sums, bbox = out            # aggregate
        counts_ok(counts, "counts")
        if sums.shape != (bs, 3) or bbox.shape != (bs, 4):
            raise CorruptOutputError(
                f"fast path returned aggregate shapes "
                f"{sums.shape}/{bbox.shape}")
        if sums.size and not np.isfinite(sums).all():
            raise CorruptOutputError(
                "fast path returned non-finite aggregate sums")

    def _check_against_ref(self, rows: np.ndarray, got, kind: str,
                           what: str) -> None:
        """Compare a fast-path sample against the oracle answer; integer
        leaves must be bit-equal, aggregate sums within the f32 tolerance."""
        want = self._ref_answer(rows, kind)
        if kind == "count":
            ok = np.array_equal(got.astype(np.int32), want)
        elif kind == "aggregate":
            ok = (np.array_equal(got[0].astype(np.int32), want[0])
                  and np.allclose(got[1], want[1], rtol=qoracle.AGG_RTOL,
                                  atol=qoracle.AGG_ATOL)
                  and np.array_equal(got[2].astype(np.int32), want[2]))
        else:
            ok = all(np.array_equal(g, w) for g, w in zip(got, want))
        if not ok:
            raise CorruptOutputError(
                f"{what} mismatch against the reference oracle")

    def _maybe_crosscheck(self, padded: np.ndarray, out, k: int,
                          kind: str = "count") -> None:
        """Healthy-state sampled oracle cross-check (silent-corruption net)."""
        cfg = self.config
        if cfg.crosscheck_every <= 0:
            return
        if self._served_batches % cfg.crosscheck_every != 0:
            return
        m = min(k, cfg.crosscheck_samples)
        if m == 0:
            return
        self._events.inc(kind="crosschecks")
        self._check_against_ref(padded[:m], self._slice_out(out, m, kind),
                                kind, "sampled cross-check")

    def _probe(self, padded: np.ndarray, k: int, kind: str = "count"):
        """Degraded-state recovery probe: one guarded fast-path attempt,
        validated against the reference on a sample before trusting it."""
        self._events.inc(kind="probes")
        try:
            self._warm_kind(kind, padded.shape[0])
            out = self._fast_batch(padded, kind)
            m = min(k, max(self.config.crosscheck_samples, 1))
            self._check_against_ref(padded[:m], self._slice_out(out, m, kind),
                                    kind, "probe cross-check")
        except Exception as e:              # probe failed; stay degraded
            self._record_fault(e)
            return None
        with self._lock:
            self.health = HEALTHY
            self._degraded_batches_since = 0
        self._events.inc(kind="recoveries")
        self._health_gauge.set(1.0)
        obs_trace.event("serve.recover")
        return out

    def _ref_answer(self, rows: np.ndarray, kind: str = "count"):
        """The degradation path: exact answers from the host placed copy,
        in the same raw shape the fast path returns (before assembly)."""
        if kind == "count":
            return ref.overlap_counts_np_chunked(rows, self._host_rects)
        pr, pi = self._placed_rects, self._placed_ids
        if kind == "ids":
            w_ids, w_cnt, _ = qoracle.ids_oracle(
                rows, pr, pi, kcap=self.config.kcap)
            return (w_ids + 1).astype(np.int32), w_cnt
        if kind == "radius":
            w_ids, w_cnt, _ = qoracle.radius_oracle(
                rows[:, :2], rows[:, 2], pr, pi, kcap=self.config.kcap)
            return (w_ids + 1).astype(np.int32), w_cnt
        if kind == "knn":
            return qoracle.knn_oracle(rows[:, :2], pr, pi,
                                      k=self.config.knn_k)
        w_cnt, w_sums, w_bbox = qoracle.aggregate_oracle(rows, pr)
        return w_cnt, w_sums.astype(np.float32), w_bbox

    def _record_fault(self, e: Exception) -> None:
        kind = ("watchdog" if isinstance(e, WatchdogTimeout)
                else "corrupt" if isinstance(e, CorruptOutputError)
                else type(e).__name__)
        self._events.inc(kind="retries")
        self._fault_counter.inc(kind=kind)
        obs_trace.event("serve.retry", kind=kind)
        with self._lock:
            self._last_fault = f"{kind}: {e}"

    def _degrade(self, e: Exception | None) -> None:
        with self._lock:
            degraded_now = self.health != DEGRADED
            if degraded_now:
                self.health = DEGRADED
                self._degraded_batches_since = 0
        if degraded_now:
            self._events.inc(kind="degradations")
            self._health_gauge.set(0.0)
            obs_trace.event("serve.degrade",
                            reason=type(e).__name__ if e else "unknown")

    def _warmup(self, bs: int) -> None:
        """Compile the (bs, 4) step once, outside the watchdog — compilation
        time must never be mistaken for a straggler."""
        padded = np.tile(self._pad_rect, (bs, 1))
        staged = self._place(padded, self._rep_sh)
        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            np.asarray(jax.device_get(self._step(*self._operands, staged)))

    # ------------------------------------------------------------- lifecycle

    def start(self) -> None:
        """Run the serving loop on a background worker thread."""
        if self._thread is not None:
            return
        self._stop_evt.clear()
        self._thread = threading.Thread(
            target=self._run, name="spatial-serve", daemon=True)
        self._thread.start()

    def _run(self) -> None:
        while not self._stop_evt.is_set():
            self.pump(block=True, timeout=0.05)

    def stop(self, drain: bool = True, timeout: float = 30.0) -> None:
        """Stop the worker; optionally drain the queue first (bounded)."""
        with self._lock:
            self._accepting = False
        if self._thread is not None:
            self._stop_evt.set()
            with self._not_empty:
                self._not_empty.notify_all()
            self._thread.join(timeout)
            self._thread = None
        if drain:
            self.drain(timeout)

    # --------------------------------------------------------------- observe

    def metrics(self) -> dict:
        """Snapshot of the health/metrics surface.

        A view over ``self.registry`` keeping the original dict shape:
        counts come from the ``serve_events_total``/``serve_faults_total``
        counter families, and latency percentiles are interpolated estimates
        from the shared fixed-bucket histograms (cumulative since server
        construction — see :class:`repro.obs.metrics.Histogram`) instead of
        a re-sorted ring per call."""
        with self._lock:
            depth = sum(len(q) for q in self._queues.values())
            health = self.health
            last_fault = self._last_fault
        c = {k: int(v) for k, v in self._events.as_dict("kind").items()}
        faults = {k: int(v)
                  for k, v in self._fault_counter.as_dict("kind").items()}
        by_kind = {k: int(v) for k, v in
                   self._kind_counter.as_dict("query_kind").items()}
        submitted = c.get("submitted", 0)
        shed = sum(v for k, v in c.items() if k.startswith("shed_"))
        return {
            "health": health,
            "queue_depth": depth,
            "submitted": submitted,
            "served": c.get("served", 0),
            "shed": shed,
            "shed_rate": shed / submitted if submitted else 0.0,
            "expired": c.get("expired", 0),
            "retries": c.get("retries", 0),
            "degradations": c.get("degradations", 0),
            "degraded_batches": c.get("degraded_batches", 0),
            "recoveries": c.get("recoveries", 0),
            "probes": c.get("probes", 0),
            "crosschecks": c.get("crosschecks", 0),
            "faults": faults,
            "queries_by_kind": by_kind,
            "last_fault": last_fault,
            "batch_p50_s": self._batch_hist.percentile(50),
            "batch_p90_s": self._batch_hist.percentile(90),
            "batch_p99_s": self._batch_hist.percentile(99),
            "request_p50_s": self._req_hist.percentile(50),
            "request_p90_s": self._req_hist.percentile(90),
            "request_p99_s": self._req_hist.percentile(99),
            "queue_wait_p50_s": self._wait_hist.percentile(50),
            "queue_wait_p99_s": self._wait_hist.percentile(99),
            "counters": c,
        }
