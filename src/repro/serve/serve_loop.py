"""Serving substrate: jitted prefill/decode steps and a batched greedy
generation driver.

Decode follows the paper's communication doctrine applied to KV caches
(DESIGN.md Sec 4): the cache — the bulky read-only array — is sharded along
its sequence axis over the 'model' mesh axis ('seq' logical axis), queries
(the new token) are broadcast, and the attention softmax reduction plays the
role of the count psum.  Batch is sharded over ('pod','data').  This is the
layout the decode_32k / long_500k dry-run cells compile.
"""
from __future__ import annotations

from typing import Any

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import api
from repro.models.base import ModelConfig
from repro.parallel.sharding import (
    logical_to_spec, param_shardings, use_mesh)


def state_shardings(cfg: ModelConfig, mesh: Mesh, state_shapes: Any):
    """KV caches: (L, B, S, H, hd) → batch over dp axes, seq over 'model'.
    Recurrent states: width over 'model'."""

    def spec_of(path, sds):
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        if sds.ndim == 5:            # (L, B, S, Hkv, hd) attention cache
            logical = (None, "batch", "seq", None, None)
        elif sds.ndim == 4:          # (L, B, conv, d_inner) ssm conv state
            logical = (None, "batch", None, "tp")
        elif sds.ndim == 3:          # (L, B, width) rglru state
            logical = (None, "batch", "tp")
        elif name.endswith("h") and sds.ndim == 4:
            logical = (None, "batch", "tp", None)
        else:
            logical = (None,) * sds.ndim
        return NamedSharding(mesh, logical_to_spec(logical, mesh, sds.shape))

    return jax.tree_util.tree_map_with_path(spec_of, state_shapes)


def batch_shardings(cfg: ModelConfig, mesh: Mesh, batch_shapes: dict):
    out = {}
    for k, sds in batch_shapes.items():
        if k == "pos_ids":
            spec = logical_to_spec((None, "batch", None), mesh, sds.shape)
        elif sds.ndim >= 2:
            spec = logical_to_spec(
                ("batch",) + (None,) * (sds.ndim - 1), mesh, sds.shape)
        else:
            spec = P()
        out[k] = NamedSharding(mesh, spec)
    return out


def make_decode_step(cfg: ModelConfig, mesh: Mesh, batch_size: int,
                     seq_len: int, dtype=jnp.bfloat16):
    """Jitted one-token decode step with explicit in/out shardings.
    This is what the decode_32k / long_500k dry-run cells lower."""
    p_shapes = api.param_shapes(cfg, dtype)
    p_sh = param_shardings(p_shapes, mesh)
    st_shapes = api.decode_state_shapes(cfg, batch_size, seq_len, dtype)
    st_sh = state_shardings(cfg, mesh, st_shapes)
    b_shapes = api.decode_batch_shapes(cfg, batch_size)
    b_sh = batch_shardings(cfg, mesh, b_shapes)

    def step(params, state, batch):
        return api.decode_step(cfg, params, state, batch)

    fn = jax.jit(step, in_shardings=(p_sh, st_sh, b_sh),
                 out_shardings=(None, st_sh), donate_argnums=(1,))
    return fn, p_shapes, st_shapes, b_shapes


def make_prefill_step(cfg: ModelConfig, mesh: Mesh, batch_size: int,
                      seq_len: int, dtype=jnp.bfloat16):
    """Jitted full-sequence forward (prefill_32k dry-run cells).

    Serving prefill needs only the last position's logits (the first sampled
    token) — materialising (B, 32768, vocab) logits would waste HBM, so the
    head applies to the final hidden state only."""
    p_shapes = api.param_shapes(cfg, dtype)
    p_sh = param_shardings(p_shapes, mesh)
    b_shapes = api.train_batch_shapes(cfg, batch_size, seq_len)
    b_sh = batch_shardings(cfg, mesh, b_shapes)

    def step(params, batch):
        hidden = api.forward(cfg, params, batch, return_hidden=True)
        return api._head_logits(cfg, params, hidden[:, -1:])

    # donate_argnums declared empty deliberately (pallint PL104): params are
    # reused by the decode path and the int32 token batch can never alias
    # the float logits, so there is nothing to donate here.
    fn = jax.jit(step, in_shardings=(p_sh, b_sh), donate_argnums=())
    return fn, p_shapes, b_shapes


def greedy_generate(cfg: ModelConfig, params, prompt: np.ndarray,
                    num_steps: int, mesh: Mesh | None = None,
                    max_seq: int = 256) -> np.ndarray:
    """Greedy decoding driver: feeds the prompt teacher-forced through the
    decode path, then samples argmax continuations.  Uniform across all
    families (attention caches, SSM states, ring buffers)."""
    b, p_len = prompt.shape
    state = api.init_decode_state(cfg, b, max_seq,
                                  dtype=jnp.float32)
    out = np.array(prompt, dtype=np.int32)
    with use_mesh(mesh):
        tok = jnp.asarray(prompt[:, :1])
        for pos in range(p_len + num_steps - 1):
            batch = {"tokens": tok, "pos": jnp.asarray(pos, jnp.int32)}
            if cfg.family == "vlm":
                batch["pos_ids"] = jnp.full((3, b, 1), pos, jnp.int32)
            logits, state = api.decode_step(cfg, params, state, batch)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            if pos + 1 < p_len:
                tok = jnp.asarray(out[:, pos + 1: pos + 2])
            else:
                tok = nxt[:, None]
                out = np.concatenate([out, np.asarray(tok)], axis=1)
    return out
