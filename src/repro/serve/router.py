"""Multi-replica spatial serving: health-checked router with failover,
hedged retries, and layout-version-aware draining (DESIGN.md Sec 13).

:mod:`repro.serve.spatial_serve` made one engine survivable; this module
makes the *service* survivable.  A :class:`SpatialRouter` fronts a
shared-nothing pool of :class:`Replica`\\ s — each replica owns its own
placed layout and its own :class:`~repro.serve.spatial_serve.SpatialServer`
(own registry, own fault state, own degradation path), so replicas share no
device buffers, no queues, and no failure domains.  The host-side analogue
of the paper's many-independent-DPUs orchestration (PIMDAL, PAPERS.md): the
router is the rank-0 coordinator, replicas are the memory units.

What the router does:

* **Health-checked routing** — each replica carries an EWMA health score fed
  by heartbeat probes (a known-answer whole-domain query cross-checked
  against the host rect count) combined with the server's own
  ``serve_healthy`` gauge and fault counters.  Routing prefers healthy
  replicas and breaks ties by queue load (least-loaded, round-robin on
  equal load).
* **Bounded failover** — a failed or timed-out attempt reroutes to the next
  healthy replica with capped exponential backoff, at most
  ``failover_attempts`` reroutes per request (PL110 doctrine: bounded, never
  except-and-retry-forever), and every reroute increments
  ``router_failovers_total{replica,reason}`` *and* emits a trace event —
  pallint PL112 machine-checks that no failover in ``src/**/serve/`` is
  silent.
* **Hedged retries** — optionally, a request still unanswered after a
  p99-derived delay is duplicated to a second replica *of the same layout
  version*; the first exact answer wins and the loser is cancelled
  (``SpatialServer.cancel``) if still queued.  The tail-at-scale recipe:
  hedging converts a straggler's p99 into roughly the p50 of two draws.
* **Layout-version-aware draining** — :meth:`SpatialRouter.swap_layout`
  rolls the pool replica-by-replica: warm the new-version replica, activate
  it, *then* drain the old one (in-flight requests finish on the layout they
  started on) and retire it.  The version fence is structural: a micro-batch
  lives inside exactly one ``SpatialServer`` which owns exactly one
  immutable placed layout, and cross-replica moves (routing, hedging,
  failover) only pair replicas whose ``layout_version`` matches the pool's
  current serving version — so no batch can ever mix layouts, and zero
  in-flight requests are dropped during a swap.
* **One observability surface** — the router's own counters
  (``router_failovers_total``, ``router_hedges_total``,
  ``router_replicas_healthy``, ...) plus every replica's server registry,
  merged by :func:`repro.obs.metrics.aggregate_prometheus` with a
  ``replica=<name>`` label per source.

Replica-level fault injection (crash / hang / poison) lives in
:class:`repro.testing.chaos.ReplicaChaos`; the chaos-router suite drives a
rolling swap under crash + straggler and asserts zero dropped / zero
duplicated responses, all bit-equal to the single-replica reference.

In no-fault steady state routed counts are bit-equal to
``BroadcastEngine.query`` — same server, same padding, same Morton ordering.
"""
from __future__ import annotations

import collections
import concurrent.futures
import dataclasses
import itertools
import threading
import time
from typing import Callable, Mapping

import numpy as np

from repro.kernels import ref
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.query import oracle as qoracle
from repro.serve import spatial_serve

# Replica lifecycle states (DESIGN.md Sec 13 state machine).
WARMING = "warming"       # engine building / step compiling; not routable
ACTIVE = "active"         # serving; routable
DRAINING = "draining"     # finishing in-flight work; not routable
RETIRED = "retired"       # drained and stopped (normal end of life)
EJECTED = "ejected"       # removed for cause (poisoned / persistent faults)

STATUS_FAILED = "failed"  # router ticket terminal state when all else fails


class ReplicaUnavailableError(RuntimeError):
    """Submit refused because the replica is not ACTIVE (the version/state
    fence: a draining or retired replica accepts no new work)."""


class Replica:
    """One shared-nothing serving replica: engine + server + lifecycle.

    ``engine_factory`` is called once (in ``__init__``, i.e. while WARMING)
    and must return a fully placed engine (``BroadcastEngine`` /
    ``SubtreeEngine``); compilation happens here so activation is cheap and
    a warming replica never counts against serving capacity.

    ``layout_version`` defaults to the placed layout's content fingerprint
    (:meth:`repro.core.engine.ShardedLayout.fingerprint`) so two replicas
    built from the same tree agree on a version without coordination.
    """

    def __init__(
        self,
        name: str,
        engine_factory: Callable[[], object],
        serve_config: spatial_serve.ServeConfig | None = None,
        *,
        layout_version: str | None = None,
        registry: obs_metrics.Registry | None = None,
    ):
        self.name = name
        self.state = WARMING
        self.registry = registry if registry is not None else (
            obs_metrics.Registry())
        self.engine = engine_factory()
        if layout_version is None:
            fp = getattr(self.engine.layout, "fingerprint", None)
            layout_version = fp() if callable(fp) else "v0"
        self.layout_version = layout_version
        self.server = spatial_serve.SpatialServer(
            self.engine, serve_config, registry=self.registry)
        self.health_score = 1.0
        self._lock = threading.Lock()
        self._inflight = 0
        self._probe_want: int | None = None
        self._last_fault_total = 0.0

    # -- lifecycle ---------------------------------------------------------

    def activate(self) -> None:
        self.server.start()
        self.state = ACTIVE

    def begin_drain(self) -> None:
        """Stop accepting new work; in-flight requests keep their slots."""
        self.state = DRAINING

    def retire(self, timeout: float = 30.0) -> None:
        """Drain the server queue and stop the worker (end of life)."""
        self.server.stop(drain=True, timeout=timeout)
        if self.state != EJECTED:
            self.state = RETIRED

    # -- serving -----------------------------------------------------------

    def submit(self, rect, *, deadline_s: float, kind: str = "count",
               radius=None):
        """Forward one request to this replica's server.

        The state fence lives here: only an ACTIVE replica accepts work, so
        a request can never land on a draining/retired/ejected replica (and
        therefore never on a layout being swapped out)."""
        if self.state != ACTIVE:
            raise ReplicaUnavailableError(
                f"replica {self.name!r} is {self.state}, not active")
        return self.server.submit(rect, kind=kind, radius=radius,
                                  deadline_s=deadline_s)

    def note_inflight(self, delta: int) -> None:
        with self._lock:
            self._inflight += delta

    @property
    def inflight(self) -> int:
        with self._lock:
            return self._inflight

    def queue_load(self) -> int:
        """Routing load signal: queued at the server + router in-flight."""
        return self.server.queue_depth + self.inflight

    # -- health ------------------------------------------------------------

    @property
    def probe_want(self) -> int:
        """Known answer for the heartbeat probe: a whole-domain query must
        count every live rect on this replica's layout."""
        if self._probe_want is None:
            self._probe_want = int(self.server._host_rects.shape[0])
        return self._probe_want

    def probe_rect(self) -> np.ndarray:
        hr = self.server._host_rects
        return np.array([hr[:, 0].min(), hr[:, 1].min(),
                         hr[:, 2].max(), hr[:, 3].max()], dtype=np.int32)

    def fault_delta(self) -> float:
        """Server faults since the last health update (EWMA penalty input)."""
        total = self.server._fault_counter.total()
        delta = total - self._last_fault_total
        self._last_fault_total = total
        return delta

    def snapshot(self) -> dict:
        return {
            "state": self.state,
            "layout_version": self.layout_version,
            "health_score": self.health_score,
            "server_health": self.server.health,
            "queue_load": self.queue_load(),
        }


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """Router policy knobs (every bound the chaos-router suite exercises)."""

    num_replicas: int = 2
    # failover
    failover_attempts: int = 2      # reroutes per request beyond the first
    attempt_timeout_s: float = 5.0  # per-attempt wait bound (hang cover)
    backoff_base_s: float = 0.01    # capped exponential between reroutes
    backoff_cap_s: float = 0.25
    default_deadline_s: float = 1.0
    # hedging
    hedge: bool = False
    hedge_delay_s: float = 0.05       # cold-start delay before p99 exists
    hedge_after_observations: int = 64  # switch to p99-derived after this
    hedge_floor_s: float = 0.002      # never hedge faster than this
    # health
    min_health: float = 0.5           # prefer replicas at/above this score
    health_alpha: float = 0.5         # EWMA step toward each probe outcome
    degraded_weight: float = 0.6      # probe outcome weight while degraded
    fault_penalty: float = 0.5        # per-new-fault multiplicative penalty
    routing_failure_decay: float = 0.25  # score *= (1-this) on submit error
    probe_interval_s: float = 0.0     # 0 = manual probe() only
    probe_deadline_s: float = 2.0
    # correctness
    crosscheck_every: int = 32        # router-level sampled oracle check
    # lifecycle
    drain_timeout_s: float = 30.0
    # plumbing
    router_workers: int = 8
    poll_interval_s: float = 0.002


class RouterTicket:
    """One routed request: completion event + result + routing trail.

    ``status`` is ``ok`` or ``failed`` (``pending`` until completed);
    ``replica`` / ``layout_version`` record who answered on which layout,
    ``attempts`` how many submissions were made (1 = no failover), and
    ``hedged`` whether a duplicate was issued.  Completion is exactly-once
    by construction (``_complete`` is guarded), so a late primary and a
    hedge can never both release a result."""

    __slots__ = ("rect", "kind", "submit_t", "deadline", "status", "reason",
                 "count", "ids", "distances", "overflow", "aggregates",
                 "replica", "layout_version", "path", "hedged", "attempts",
                 "latency_s", "_event", "_lock")

    def __init__(self, rect: np.ndarray, submit_t: float, deadline: float,
                 kind: str = "count"):
        self.rect = rect
        self.kind = kind
        self.submit_t = submit_t
        self.deadline = deadline
        self.status = spatial_serve.STATUS_PENDING
        self.reason = None
        self.count = None
        self.ids = None
        self.distances = None
        self.overflow = None
        self.aggregates = None
        self.replica = None
        self.layout_version = None
        self.path = None
        self.hedged = False
        self.attempts = 0
        self.latency_s = None
        self._event = threading.Event()
        self._lock = threading.Lock()

    @property
    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: float | None = None) -> bool:
        return self._event.wait(timeout)

    def _complete(self, **fields) -> bool:
        """Set terminal fields exactly once; False if already completed."""
        with self._lock:
            if self._event.is_set():
                return False
            for k, v in fields.items():
                setattr(self, k, v)
            self._event.set()
            return True


class SpatialRouter:
    """Health-checked router over a pool of shared-nothing replicas.

    ``submit`` is thread-safe and non-blocking: each request is driven to
    completion (route → await → failover/hedge → verify → complete) by one
    worker from an internal pool, so a straggling replica never blocks
    admission.  ``swap_layout`` rolls the pool to a new index build with
    zero dropped in-flight requests.
    """

    def __init__(
        self,
        engine_factory: Callable[[], object],
        *,
        config: RouterConfig | None = None,
        serve_config: spatial_serve.ServeConfig | None = None,
        layout_version: str | None = None,
        registry: obs_metrics.Registry | None = None,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
    ):
        self.config = config or RouterConfig()
        self._serve_config = serve_config
        self._clock = clock
        self._sleep = sleep

        self.registry = registry if registry is not None else (
            obs_metrics.Registry())
        r = self.registry
        self._requests = r.counter(
            "router_requests_total", "requests admitted by the router")
        self._responses = r.counter(
            "router_responses_total", "terminal responses by status")
        self._failovers = r.counter(
            "router_failovers_total",
            "reroutes after a replica attempt failed, by replica and reason")
        self._hedges = r.counter(
            "router_hedges_total", "hedged duplicates issued")
        self._hedge_wins = r.counter(
            "router_hedge_wins_total", "requests answered by the hedge")
        self._hedge_cancels = r.counter(
            "router_hedge_cancels_total",
            "losing duplicates cancelled before being served")
        self._ejections = r.counter(
            "router_ejections_total", "replicas removed for cause")
        self._swaps = r.counter(
            "router_layout_swaps_total", "completed rolling layout swaps")
        self._probe_failures = r.counter(
            "router_probe_failures_total", "failed heartbeat probes")
        self._crosschecks = r.counter(
            "router_crosschecks_total", "router-level sampled oracle checks")
        self._healthy_gauge = r.gauge(
            "router_replicas_healthy",
            "active replicas at/above the min_health score")
        self._state_gauge = r.gauge(
            "router_replicas", "replicas by lifecycle state")
        self._req_hist = r.histogram(
            "router_request_latency_seconds",
            "submit-to-completion latency of routed requests")

        self._lock = threading.Lock()          # replica list + rr counter
        self._swap_lock = threading.Lock()     # one swap at a time
        self._replicas: list[Replica] = []
        self._retired: list[Replica] = []
        self._rr = itertools.count()
        self._completions = 0
        self._accepting = True
        self._stop_evt = threading.Event()
        self._probe_thread: threading.Thread | None = None
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=self.config.router_workers,
            thread_name_prefix="spatial-router")

        self.layout_version = None
        for i in range(self.config.num_replicas):
            rep = self._add_replica(f"r{i}", engine_factory, layout_version)
            if self.layout_version is None:
                self.layout_version = rep.layout_version
        self._update_pool_gauges()

    # -- pool management ---------------------------------------------------

    def _add_replica(self, name: str, factory, version: str | None) -> Replica:
        rep = Replica(name, factory, self._serve_config,
                      layout_version=version)
        rep.activate()
        with self._lock:
            self._replicas.append(rep)
        obs_trace.event("router.replica_active", replica=name,
                        version=rep.layout_version)
        return rep

    def replicas(self) -> list[Replica]:
        with self._lock:
            return list(self._replicas)

    def _eject(self, rep: Replica, reason: str) -> None:
        """Remove a replica for cause (wrong answers / persistent faults)."""
        with self._lock:
            if rep.state == EJECTED:
                return
            rep.state = EJECTED
            rep.health_score = 0.0
        self._ejections.inc(reason=reason)
        obs_trace.event("router.eject", replica=rep.name, reason=reason)
        rep.server.stop(drain=False, timeout=1.0)
        self._update_pool_gauges()

    def _update_pool_gauges(self) -> None:
        reps = self.replicas()
        healthy = sum(1 for r in reps if r.state == ACTIVE
                      and r.health_score >= self.config.min_health)
        self._healthy_gauge.set(healthy)
        counts = collections.Counter(r.state for r in reps + self._retired)
        for state in (WARMING, ACTIVE, DRAINING, RETIRED, EJECTED):
            self._state_gauge.set(counts.get(state, 0), state=state)

    # -- admission ---------------------------------------------------------

    def submit(self, rect, *, kind: str = "count", radius=None,
               deadline_s: float | None = None) -> RouterTicket:
        """Admit one request; a worker drives it to completion.

        ``kind``/``radius`` follow :meth:`SpatialServer.submit` — the same
        strict per-kind validation runs here, at the routing boundary, so a
        malformed request is refused before any replica sees it.  Always
        returns a ticket; terminal status is ``ok`` (with the kind's result
        fields) or ``failed`` (with ``reason``) — never silently dropped."""
        payload = spatial_serve.pack_request(
            rect, kind, radius, where=f"SpatialRouter.submit[{kind}]")
        now = self._clock()
        if deadline_s is None:
            deadline_s = self.config.default_deadline_s
        task = RouterTicket(payload, now, now + deadline_s, kind=kind)
        self._requests.inc(query_kind=kind)
        if not self._accepting:
            self._finish(task, reason="stopped")
            return task
        self._pool.submit(self._run_task, task)
        return task

    @staticmethod
    def _forward(rep: Replica, task: RouterTicket, *, deadline_s: float):
        """Resubmit a task's packed payload row to one replica in the raw
        per-kind form the server admission boundary expects."""
        if task.kind in ("knn", "radius"):
            radius = int(task.rect[2]) if task.kind == "radius" else None
            return rep.submit(task.rect[:2], kind=task.kind, radius=radius,
                              deadline_s=deadline_s)
        return rep.submit(task.rect, kind=task.kind, deadline_s=deadline_s)

    def _run_task(self, task: RouterTicket) -> None:
        try:
            self._serve_one(task)
        except Exception as e:
            # Last-resort net: a router bug must still fail the ticket
            # explicitly — a routed request is never dropped on the floor.
            self._finish(task, reason=f"internal:{type(e).__name__}")
        if not task.done:
            self._finish(task, reason="exhausted")

    # -- the per-request routing loop -------------------------------------

    def _serve_one(self, task: RouterTicket) -> None:
        cfg = self.config
        tried: set[str] = set()
        for attempt in range(cfg.failover_attempts + 1):
            if self._clock() >= task.deadline:
                self._finish(task, reason="deadline")
                return
            rep = self._pick(tried)
            if rep is None and tried:
                tried = set()              # every replica tried once: reset
                rep = self._pick(tried)
            if rep is None:
                self._finish(task, reason="no_replicas")
                return
            tried.add(rep.name)
            task.attempts += 1
            try:
                budget = task.deadline - self._clock()
                sub = self._forward(rep, task, deadline_s=budget)
            except Exception as e:
                self._record_failover(rep, type(e).__name__)
                self._note_routing_failure(rep)
                self._backoff(attempt)
                continue
            rep.note_inflight(+1)
            try:
                if self._await(task, rep, sub, tried):
                    return
            finally:
                rep.note_inflight(-1)
            self._record_failover(rep, sub.status if sub.done else "timeout")
            self._note_routing_failure(rep)
            self._backoff(attempt)
        self._finish(task, reason="exhausted")

    def _await(self, task: RouterTicket, rep: Replica, sub,
               tried: set[str]) -> bool:
        """Poll one submitted attempt to a verdict; optionally hedge.

        One worker drives both the primary and its hedge, so completion is
        single-threaded per request (the ticket ``_complete`` lock is the
        belt-and-braces second line).  Returns True iff the task completed."""
        cfg = self.config
        deadline_eff = min(task.deadline,
                           self._clock() + cfg.attempt_timeout_s)
        hedge_rep = hedge_sub = None
        hedge_at = (self._clock() + self._hedge_delay()
                    if cfg.hedge else None)
        try:
            while True:
                if rep.state == EJECTED and not sub.done:
                    return False           # waiters on an ejected replica bail
                if sub.done:
                    if self._accept(task, rep, sub):
                        self._cancel_hedge(hedge_rep, hedge_sub)
                        return True
                    self._cancel_hedge(hedge_rep, hedge_sub)
                    return False
                if hedge_sub is not None and hedge_sub.done:
                    if self._accept(task, hedge_rep, hedge_sub,
                                    hedged=True):
                        self._hedge_wins.inc()
                        self._cancel_hedge(rep, sub)
                        return True
                    hedge_rep.note_inflight(-1)    # hedge failed; primary on
                    hedge_rep = hedge_sub = None
                now = self._clock()
                if now >= deadline_eff:
                    self._cancel_hedge(rep, sub)
                    if hedge_sub is not None:
                        self._cancel_hedge(hedge_rep, hedge_sub)
                    return False
                if (hedge_at is not None and hedge_sub is None
                        and now >= hedge_at):
                    hedge_at = None        # one hedge per attempt
                    hedge_rep, hedge_sub = self._issue_hedge(task, rep, tried)
                self._sleep(cfg.poll_interval_s)
        finally:
            if hedge_rep is not None:
                hedge_rep.note_inflight(-1)

    def _issue_hedge(self, task: RouterTicket, primary: Replica,
                     tried: set[str]):
        """Duplicate the request to a second same-version replica."""
        rep = self._pick(tried | {primary.name},
                         version=primary.layout_version)
        if rep is None:
            return None, None
        try:
            budget = task.deadline - self._clock()
            sub = self._forward(rep, task, deadline_s=budget)
        except Exception as e:
            self._record_failover(rep, type(e).__name__)
            self._note_routing_failure(rep)
            return None, None
        rep.note_inflight(+1)
        task.hedged = True
        self._hedges.inc()
        obs_trace.event("router.hedge", primary=primary.name,
                        hedge=rep.name)
        return rep, sub

    def _cancel_hedge(self, rep: Replica | None, sub) -> None:
        """Withdraw the losing duplicate if it is still queued (a duplicate
        already mid-batch finishes and is discarded — duplicate *work* is
        tolerated, duplicate *responses* are not)."""
        if rep is None or sub is None or sub.done:
            return
        if rep.server.cancel(sub, reason="hedge_lost"):
            self._hedge_cancels.inc()

    def _hedge_delay(self) -> float:
        cfg = self.config
        if self._req_hist.count >= cfg.hedge_after_observations:
            p99 = self._req_hist.percentile(99)
            if p99 is not None:
                return max(p99, cfg.hedge_floor_s)
        return max(cfg.hedge_delay_s, cfg.hedge_floor_s)

    # -- completion --------------------------------------------------------

    def _accept(self, task: RouterTicket, rep: Replica, sub,
                *, hedged: bool = False) -> bool:
        """Judge one finished server ticket; complete the task on success."""
        if sub.status != spatial_serve.STATUS_OK:
            return False                   # shed/expired/cancelled: not ours
        if not self._verify(task, rep, sub):
            return False                   # poisoned: replica ejected
        now = self._clock()
        latency = now - task.submit_t
        if task._complete(status=spatial_serve.STATUS_OK, count=sub.count,
                          ids=sub.ids, distances=sub.distances,
                          overflow=sub.overflow, aggregates=sub.aggregates,
                          replica=rep.name,
                          layout_version=rep.layout_version,
                          path=sub.path, latency_s=latency):
            self._responses.inc(status="ok")
            self._req_hist.observe(latency)
        return True

    def _verify(self, task: RouterTicket, rep: Replica, sub) -> bool:
        """Router-level sampled oracle cross-check (poisoned-replica net).

        The replica's own server cross-checks its batches, but a poisoned
        step can still return plausible in-bounds counts; sampling here —
        above the replica boundary — catches a replica that lies
        consistently, and ejects it."""
        cfg = self.config
        if cfg.crosscheck_every <= 0:
            return True
        with self._lock:
            self._completions += 1
            sampled = self._completions % cfg.crosscheck_every == 0
        if not sampled:
            return True
        self._crosschecks.inc()
        if self._answer_matches_oracle(task, rep, sub):
            return True
        self._eject(rep, "poisoned")
        return False

    @staticmethod
    def _answer_matches_oracle(task: RouterTicket, rep: Replica,
                               sub) -> bool:
        """Compare one finished server ticket against the replica's host
        oracle, per kind (integer fields bit-equal, aggregate sums within
        the documented f32 tolerance)."""
        kind = task.kind
        if kind == "count":
            want = int(ref.overlap_counts_np_chunked(
                task.rect.reshape(1, 4), rep.server._host_rects)[0])
            return int(sub.count) == want
        rows = task.rect.reshape(1, 4)
        want = rep.server._ref_answer(rows, kind)
        if kind in ("ids", "radius"):
            slots, cnt = want
            return (int(sub.count) == int(cnt[0])
                    and np.array_equal(sub.ids, slots[0] - 1))
        if kind == "knn":
            w_d, w_i = want
            return (np.array_equal(sub.ids, w_i[0])
                    and np.array_equal(sub.distances, w_d[0]))
        cnt, sums, bbox = want              # aggregate
        return (int(sub.count) == int(cnt[0])
                and np.array_equal(sub.aggregates["bbox"], bbox[0])
                and np.allclose(sub.aggregates["sums"], sums[0],
                                rtol=qoracle.AGG_RTOL,
                                atol=qoracle.AGG_ATOL))

    def _finish(self, task: RouterTicket, *, reason: str) -> None:
        if task._complete(status=STATUS_FAILED, reason=reason,
                          latency_s=self._clock() - task.submit_t):
            self._responses.inc(status="failed")
            obs_trace.event("router.fail", reason=reason)

    def _record_failover(self, rep: Replica, reason: str) -> None:
        self._failovers.inc(replica=rep.name, reason=reason)
        obs_trace.event("router.failover", replica=rep.name, reason=reason)

    def _note_routing_failure(self, rep: Replica) -> None:
        rep.health_score *= 1.0 - self.config.routing_failure_decay
        self._update_pool_gauges()

    def _backoff(self, attempt: int) -> None:
        self._sleep(min(self.config.backoff_base_s * (2 ** attempt),
                        self.config.backoff_cap_s))

    # -- routing policy ----------------------------------------------------

    def _pick(self, exclude: set[str],
              version: str | None = None) -> Replica | None:
        """Least-loaded healthy ACTIVE replica not in ``exclude``.

        ``version`` pins the choice to one layout version (hedge pairing);
        unpinned picks are implicitly fenced too, because only ACTIVE
        replicas are candidates and a swap drains old-version replicas out
        of ACTIVE before the pool serves two versions steadily."""
        cfg = self.config
        with self._lock:
            cands = [r for r in self._replicas
                     if r.state == ACTIVE and r.name not in exclude
                     and (version is None or r.layout_version == version)]
            rr = next(self._rr)
        if not cands:
            return None
        healthy = [r for r in cands if r.health_score >= cfg.min_health]
        pool = healthy or cands            # all sick: still route (degraded)
        load = min(r.queue_load() for r in pool)
        tied = [r for r in pool if r.queue_load() == load]
        return tied[rr % len(tied)]

    # -- health probes -----------------------------------------------------

    def probe(self) -> dict[str, bool]:
        """One heartbeat round: known-answer query per ACTIVE replica.

        Returns ``{name: ok}`` and folds each outcome into the replica's
        EWMA health score (weighted down while the server is degraded,
        multiplied down per new server fault since the last round)."""
        cfg = self.config
        results: dict[str, bool] = {}
        for rep in self.replicas():
            if rep.state != ACTIVE:
                continue
            ok = self._probe_one(rep)
            results[rep.name] = ok
            outcome = 1.0 if ok else 0.0
            if ok and rep.server.health == spatial_serve.DEGRADED:
                outcome = cfg.degraded_weight
            outcome *= cfg.fault_penalty ** min(rep.fault_delta(), 3.0)
            rep.health_score = ((1.0 - cfg.health_alpha) * rep.health_score
                                + cfg.health_alpha * outcome)
            if not ok:
                self._probe_failures.inc(replica=rep.name)
                obs_trace.event("router.probe_fail", replica=rep.name)
        self._update_pool_gauges()
        return results

    def _probe_one(self, rep: Replica) -> bool:
        try:
            t = rep.submit(rep.probe_rect(),
                           deadline_s=self.config.probe_deadline_s)
        except Exception:
            return False
        if not t.wait(self.config.probe_deadline_s + 0.5):
            return False
        return (t.status == spatial_serve.STATUS_OK
                and int(t.count) == rep.probe_want)

    def start(self) -> None:
        """Start the periodic heartbeat prober (no-op when interval is 0)."""
        if self.config.probe_interval_s <= 0 or self._probe_thread is not None:
            return
        self._stop_evt.clear()

        def loop():
            while not self._stop_evt.wait(self.config.probe_interval_s):
                self.probe()

        self._probe_thread = threading.Thread(
            target=loop, name="router-probe", daemon=True)
        self._probe_thread.start()

    # -- rolling layout swap ----------------------------------------------

    def swap_layout(self, engine_factory: Callable[[], object],
                    *, version: str | None = None) -> None:
        """Roll the pool onto a new index build, replica by replica.

        For each old-version replica: warm + activate its same-name
        successor on the new layout, *then* drain the old one (it finishes
        every request it accepted — zero dropped in-flight) and retire it.
        New requests route to whatever is ACTIVE at pick time; each request
        is answered entirely by one replica on one layout, so no batch ever
        mixes versions (machine-checked in tests/test_router.py)."""
        with self._swap_lock:
            old = [r for r in self.replicas() if r.state == ACTIVE]
            new_version = version
            for i, rep in enumerate(old):
                nrep = self._add_replica(
                    f"{rep.name}'", engine_factory, version)
                if new_version is None:
                    new_version = nrep.layout_version
                obs_trace.event("router.swap_step", old=rep.name,
                                new=nrep.name, version=nrep.layout_version)
                rep.begin_drain()
                self._drain_replica(rep)
                rep.retire(self.config.drain_timeout_s)
                with self._lock:
                    self._replicas.remove(rep)
                    self._retired.append(rep)
                self._update_pool_gauges()
            self.layout_version = new_version
            self._swaps.inc()
            obs_trace.event("router.swap_done", version=new_version)

    def _drain_replica(self, rep: Replica) -> None:
        """Bounded wait for router in-flight work on ``rep`` to finish."""
        deadline = self._clock() + self.config.drain_timeout_s
        while self._clock() < deadline:
            if rep.inflight == 0 and rep.server.queue_depth == 0:
                return
            self._sleep(self.config.poll_interval_s)

    # -- lifecycle ---------------------------------------------------------

    def stop(self, drain: bool = True, timeout: float = 30.0) -> None:
        self._accepting = False
        self._stop_evt.set()
        if self._probe_thread is not None:
            self._probe_thread.join(timeout)
            self._probe_thread = None
        self._pool.shutdown(wait=drain)
        for rep in self.replicas():
            rep.server.stop(drain=drain, timeout=timeout)
        self._update_pool_gauges()

    # -- observability -----------------------------------------------------

    def metrics(self) -> dict:
        """Router health surface (the dict the bench/demo persist)."""
        self._update_pool_gauges()     # health scores may have moved since
        reps = self.replicas()
        return {
            "layout_version": self.layout_version,
            "replicas": {r.name: r.snapshot() for r in reps},
            "replicas_healthy": int(self._healthy_gauge.value()),
            "requests": int(self._requests.total()),
            "requests_by_kind": {
                k: int(v) for k, v in
                self._requests.as_dict("query_kind").items()},
            "responses_ok": int(self._responses.value(status="ok")),
            "responses_failed": int(self._responses.value(status="failed")),
            "failovers": int(self._failovers.total()),
            "hedges": int(self._hedges.value()),
            "hedge_wins": int(self._hedge_wins.value()),
            "hedge_cancels": int(self._hedge_cancels.value()),
            "ejections": int(self._ejections.total()),
            "layout_swaps": int(self._swaps.value()),
            "crosschecks": int(self._crosschecks.value()),
            "request_p50_s": self._req_hist.percentile(50),
            "request_p99_s": self._req_hist.percentile(99),
        }

    def _replica_registries(self) -> Mapping[str, obs_metrics.Registry]:
        return {r.name: r.registry
                for r in self.replicas() + self._retired}

    def prometheus_text(self) -> str:
        """One scrape surface: router series unlabeled, every replica's
        server series tagged ``replica=<name>``."""
        return obs_metrics.aggregate_prometheus(
            self._replica_registries(), label="replica", base=self.registry)

    def snapshot(self) -> dict:
        return {
            "router": self.registry.snapshot(),
            "replicas": {name: reg.snapshot()
                         for name, reg in self._replica_registries().items()},
        }
