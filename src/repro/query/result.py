"""Typed result wrapper for the query surface (DESIGN.md Sec 14).

Every engine query kind returns a :class:`SpatialResult` instead of a bare
array, because the kinds stop sharing an output shape the moment results are
materialized: range/radius queries produce *ID lists* with a fixed capacity
and an overflow account, kNN produces *(distance, ID)* frontiers, aggregates
produce per-query statistics.  The wrapper keeps the fixed-shape device
buffers as-is (no ragged host lists on the hot path) and derives the
user-facing views lazily on the host.

Conventions carried over from the kernels:

* ``ids`` rows are source-rect indices in ascending *placed* order for
  ``ids``/``radius`` kinds and ascending ``(distance, id)`` order for
  ``knn``; ``-1`` marks an empty slot.
* ``count`` is always the *true* total number of matches — when a range or
  radius query matches more than ``kcap`` rects, ``ids`` holds the first
  ``kcap`` of them and ``overflow = count - kcap`` records the truncation
  (never silent).
* Aggregate sums are float32 on-fabric accumulations; ``centroid`` and
  ``mean_area`` divide them on the host in float64 and return NaN for
  queries with zero matches rather than raising.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

KINDS = ("count", "ids", "knn", "radius", "aggregate")


@dataclasses.dataclass(frozen=True)
class SpatialResult:
    """One query batch's results for a single query kind.

    Fields are ``None`` when the kind does not produce them:

    ==========  =========  ========================================
    field       kinds      shape / meaning
    ==========  =========  ========================================
    count       all        (Q,) int32 true match totals
    ids         ids/radius (Q, kcap) int32, -1 empty, placed order
                knn        (Q, k) int32, (distance, id) order
    distances   knn        (Q, k) float32 squared distances, inf empty
    overflow    ids/radius (Q,) int32 matches dropped past kcap
    aggregates  aggregate  {"sums": (Q, 3) f32, "bbox": (Q, 4) i32}
    ==========  =========  ========================================
    """

    kind: str
    count: np.ndarray
    ids: np.ndarray | None = None
    distances: np.ndarray | None = None
    overflow: np.ndarray | None = None
    aggregates: dict[str, np.ndarray] | None = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown query kind {self.kind!r}")

    def __len__(self) -> int:
        return int(self.count.shape[0])

    # ------------------------------------------------------------- id views

    @property
    def num_queries(self) -> int:
        return int(self.count.shape[0])

    @property
    def total_overflow(self) -> int:
        """Total matches dropped across the batch (0 when kind has no cap)."""
        if self.overflow is None:
            return 0
        return int(self.overflow.sum())

    @property
    def truncated(self) -> np.ndarray:
        """(Q,) bool — which queries lost matches to the kcap ceiling."""
        if self.overflow is None:
            return np.zeros(self.num_queries, dtype=bool)
        return self.overflow > 0

    def ids_for(self, i: int) -> np.ndarray:
        """The materialized IDs of query ``i``, trimmed of empty slots."""
        if self.ids is None:
            raise ValueError(f"kind {self.kind!r} has no materialized ids")
        row = self.ids[i]
        return row[row >= 0]

    # ------------------------------------------------------ aggregate views

    def _agg(self, key: str) -> np.ndarray:
        if self.aggregates is None:
            raise ValueError(f"kind {self.kind!r} has no aggregates")
        return self.aggregates[key]

    @property
    def centroid(self) -> np.ndarray:
        """(Q, 2) float64 mean rect centre; NaN rows where count == 0.

        On-fabric the kernel accumulates ``Σ(x0+x1)`` / ``Σ(y0+y1)``; the
        centre of rect ``r`` is ``((x0+x1)/2, (y0+y1)/2)``, so the mean
        centre is the sums over ``2·count``."""
        sums = self._agg("sums").astype(np.float64)
        cnt = self.count.astype(np.float64)
        with np.errstate(divide="ignore", invalid="ignore"):
            out = sums[:, :2] / (2.0 * cnt[:, None])
        out[cnt == 0] = np.nan
        return out

    @property
    def mean_area(self) -> np.ndarray:
        """(Q,) float64 mean matched-rect area; NaN where count == 0."""
        sums = self._agg("sums").astype(np.float64)
        cnt = self.count.astype(np.float64)
        with np.errstate(divide="ignore", invalid="ignore"):
            out = sums[:, 2] / cnt
        out[cnt == 0] = np.nan
        return out

    @property
    def bbox(self) -> np.ndarray:
        """(Q, 4) int32 bbox of matches (EMPTY orientation when none)."""
        return self._agg("bbox")

    # ----------------------------------------------------------- conversion

    def to_numpy(self) -> dict[str, Any]:
        """Plain-array dict view (stable serialization surface)."""
        out: dict[str, Any] = {"kind": self.kind,
                               "count": np.asarray(self.count)}
        if self.ids is not None:
            out["ids"] = np.asarray(self.ids)
        if self.distances is not None:
            out["distances"] = np.asarray(self.distances)
        if self.overflow is not None:
            out["overflow"] = np.asarray(self.overflow)
        if self.aggregates is not None:
            out["sums"] = np.asarray(self.aggregates["sums"])
            out["bbox"] = np.asarray(self.aggregates["bbox"])
        return out
