"""NumPy reference oracles for the query surface.

Each oracle is the ground truth a kernel path must match — bit-equal for
integer outputs (counts, IDs, bboxes, distances under the shared f32 metric),
and within a documented tolerance for the float aggregate sums, which the
oracle therefore accumulates in float64 (``AGG_RTOL``).

All oracles take the **placed** rect arrays — the per-device slices
concatenated in device order, exactly the rows the kernels stream, including
EMPTY padding (``lo > hi``) slots which never match anything — plus the
aligned source-ID vector (``-1`` on padding).  "Placed order" is the order
in which materialized IDs come back from the engines, so ``ids_oracle`` /
``radius_oracle`` outputs compare with ``==``, no sorting slack.

The distance metric is the shared three-step contract of
:mod:`repro.kernels.knn`: exact int32 clip to the rect, then float32
subtract/square/add.  :func:`point_rect_dist2` performs those float32
operations in the same order as the kernel and the XLA twin, so kNN and
radius results are IEEE-deterministic across all three implementations.

These oracles double as the serving layer's degradation path: when the fast
path is down, :class:`repro.serve.spatial_serve.SpatialServer` answers
ids/knn/radius/aggregate requests from here over the host rect copy.
"""
from __future__ import annotations

import numpy as np

AGG_RTOL = 1e-5   # f32 on-fabric sums vs this float64 oracle
AGG_ATOL = 1e-6

_INT32_MAX = 2**31 - 1
_INT32_MIN = -(2**31)


def _valid(rects: np.ndarray) -> np.ndarray:
    r = np.asarray(rects)
    return (r[:, 0] <= r[:, 2]) & (r[:, 1] <= r[:, 3])


def overlap_matrix(queries: np.ndarray, rects: np.ndarray) -> np.ndarray:
    """(Q, R) bool closed-interval overlap; EMPTY rows never match."""
    q = np.asarray(queries)
    r = np.asarray(rects)
    return (
        (q[:, None, 0] <= r[None, :, 2]) & (r[None, :, 0] <= q[:, None, 2])
        & (q[:, None, 1] <= r[None, :, 3]) & (r[None, :, 1] <= q[:, None, 3])
        & _valid(r)[None, :]
    )


def point_rect_dist2(points: np.ndarray, rects: np.ndarray) -> np.ndarray:
    """(Q, R) squared f32 point-to-rect distances — the shared metric.

    Same operations, same order, same dtypes as the Pallas kernel and the
    XLA twin: int32 clip (max then min, matching ``jnp.clip``), then f32
    subtract / multiply / add.  The device paths wrap each square in
    ``maximum(.., 0)`` purely as an FMA-contraction barrier (see
    ``repro.kernels.knn._pairwise_dist2``) so that both products round
    separately — i.e. so they compute *this* plain NumPy expression
    bit-exactly.  Rows for invalid (EMPTY) rects are garbage — mask with
    :func:`_valid` like the kernels do.
    """
    p = np.asarray(points, dtype=np.int32)
    r = np.asarray(rects, dtype=np.int32)
    px = p[:, 0][:, None]
    py = p[:, 1][:, None]
    cx = np.minimum(np.maximum(px, r[:, 0][None, :]), r[:, 2][None, :])
    cy = np.minimum(np.maximum(py, r[:, 1][None, :]), r[:, 3][None, :])
    dx = px.astype(np.float32) - cx.astype(np.float32)
    dy = py.astype(np.float32) - cy.astype(np.float32)
    return dx * dx + dy * dy


def _pack_ids(hit: np.ndarray, ids: np.ndarray, kcap: int
              ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Shared tail of the materializing oracles: first-kcap IDs per query in
    placed order, true counts, and overflow."""
    q = hit.shape[0]
    counts = hit.sum(axis=1).astype(np.int32)
    out = np.full((q, kcap), -1, dtype=np.int32)
    for i in range(q):
        match = ids[hit[i]]
        out[i, : min(kcap, match.shape[0])] = match[:kcap]
    overflow = np.maximum(counts - kcap, 0).astype(np.int32)
    return out, counts, overflow


def ids_oracle(queries: np.ndarray, rects: np.ndarray, ids: np.ndarray,
               *, kcap: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Range-query materialization: ``(ids (Q, kcap), counts, overflow)``."""
    return _pack_ids(overlap_matrix(queries, rects),
                     np.asarray(ids, dtype=np.int32), kcap)


def radius_oracle(points: np.ndarray, radii: np.ndarray, rects: np.ndarray,
                  ids: np.ndarray, *, kcap: int
                  ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Closed-ball radius query under the shared f32 metric (``d2 <= r*r``
    with the radius squared in float32, exactly like the kernels)."""
    rad = np.asarray(radii, dtype=np.int32)
    d2 = point_rect_dist2(points, rects)
    r2 = rad.astype(np.float32) * rad.astype(np.float32)
    hit = _valid(rects)[None, :] & (rad >= 0)[:, None] & (d2 <= r2[:, None])
    return _pack_ids(hit, np.asarray(ids, dtype=np.int32), kcap)


def knn_oracle(points: np.ndarray, rects: np.ndarray, ids: np.ndarray,
               *, k: int) -> tuple[np.ndarray, np.ndarray]:
    """k nearest rects: ``(dists (Q, k) f32 ascending, ids (Q, k))``.

    Ties broken by ascending source ID via lexsort on ``(d2, id)`` — the
    same two-key order as the kernels' ``jax.lax.sort``.  Slots past the
    number of valid rects hold ``(inf, -1)``.
    """
    p = np.asarray(points, dtype=np.int32)
    idv = np.asarray(ids, dtype=np.int32)
    valid = _valid(rects)
    d2 = point_rect_dist2(p, rects)
    q = p.shape[0]
    out_d = np.full((q, k), np.inf, dtype=np.float32)
    out_i = np.full((q, k), -1, dtype=np.int32)
    vd2 = d2[:, valid]
    vids = idv[valid]
    for i in range(q):
        order = np.lexsort((vids, vd2[i]))[:k]
        out_d[i, : order.shape[0]] = vd2[i][order]
        out_i[i, : order.shape[0]] = vids[order]
    return out_d, out_i


def aggregate_oracle(queries: np.ndarray, rects: np.ndarray
                     ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Float64 aggregate reference: ``(counts (Q,) i32, sums (Q, 3) f64
    [Σ(x0+x1), Σ(y0+y1), Σ area], bbox (Q, 4) i32 EMPTY-oriented)``.

    The engines' f32 on-fabric sums must match within ``AGG_RTOL`` /
    ``AGG_ATOL``; counts and bbox must match exactly.
    """
    q = np.asarray(queries, dtype=np.int32)
    r = np.asarray(rects, dtype=np.int64)    # pallint: disable=PL109
    hit = overlap_matrix(q, rects)
    counts = hit.sum(axis=1).astype(np.int32)
    rf = r.astype(np.float64)
    cx = rf[:, 0] + rf[:, 2]
    cy = rf[:, 1] + rf[:, 3]
    area = (rf[:, 2] - rf[:, 0]) * (rf[:, 3] - rf[:, 1])
    sums = np.stack([
        np.where(hit, cx[None, :], 0.0).sum(axis=1),
        np.where(hit, cy[None, :], 0.0).sum(axis=1),
        np.where(hit, area[None, :], 0.0).sum(axis=1),
    ], axis=1)
    ri = np.asarray(rects, dtype=np.int32)
    bbox = np.stack([
        np.where(hit, ri[:, 0][None, :], _INT32_MAX).min(axis=1),
        np.where(hit, ri[:, 1][None, :], _INT32_MAX).min(axis=1),
        np.where(hit, ri[:, 2][None, :], _INT32_MIN).max(axis=1),
        np.where(hit, ri[:, 3][None, :], _INT32_MIN).max(axis=1),
    ], axis=1).astype(np.int32)
    return counts, sums, bbox
