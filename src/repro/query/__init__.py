"""Result-materializing query surface: ids / knn / radius / aggregate.

Turns the count-only engines into a full query subsystem (DESIGN.md
Sec 14).  The public pieces:

* :class:`repro.query.result.SpatialResult` — the typed result wrapper
  every ``query_*`` engine method returns;
* :mod:`repro.query.pipelines` — the SPMD step factory + payload packing
  shared by both engines and the serving layer;
* :mod:`repro.query.oracle` — NumPy ground truth for every kind (also the
  serving degradation path).
"""
from repro.query.result import KINDS, SpatialResult
from repro.query.pipelines import QUERY_KINDS, make_kind_step

__all__ = ["KINDS", "QUERY_KINDS", "SpatialResult", "make_kind_step"]
