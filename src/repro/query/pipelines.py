"""SPMD step factories and batch plumbing for the query surface.

One factory, four kinds.  :func:`make_kind_step` builds a jitted shard_map
step with the *same* operand signature for every kind and both engines::

    step(leaf_coords, leaf_ids, rect_tile_mbrs, cover_mbrs, payload)

— leaf coordinates sharded over all mesh axes (axis 1 of the (4, N)
layout), source IDs sharded the same way on axis 0, tile metadata and
Phase-1 covers one-row-per-device, and the payload replicated and donated.
Engines pick the operands; the factory picks the math.  Kinds that don't
need an operand (aggregate ignores IDs, the distance kinds ignore covers)
still take it, so the serving layer can cache one operand tuple per engine.

Every payload is a fixed ``(B, 4)`` int32 array so micro-batching, EMPTY
padding, and donation reuse the count path's plumbing verbatim:

=========  ==================================  ======================
kind       payload row                         pad row
=========  ==================================  ======================
ids        ``[x0, y0, x1, y1]``                EMPTY rect
knn        ``[x, y, 0, 0]``                    ``[0, 0, 0, 0]``
radius     ``[x, y, r, 0]``                    ``[0, 0, -1, 0]``
aggregate  ``[x0, y0, x1, y1]``                EMPTY rect
=========  ==================================  ======================

(EMPTY rects match nothing; a negative radius marks padding for the radius
kernel's ``rad >= 0`` gate; kNN pad rows compute a real frontier for the
origin that the caller slices off.)

Cross-device result combination happens **on fabric**, inside the step —
never on the host (ids/radius would otherwise need a host gather of
per-device candidate lists, the exact pattern pallint PL113 bans):

* ids/radius — two passes.  Pass 1 counts locally; a one-hot outer product
  ``psum`` gathers the (D, B) count table everywhere without an
  ``all_gather`` dependency, giving each device its exclusive global slot
  offset (devices hold *contiguous placed slices*, so global result order =
  device order = placed order).  Pass 2 scatters ``id+1`` into the device's
  disjoint slot range of the shared (B, kcap) buffer; a final ``psum``
  merges the disjoint ranges.  Single device skips pass 1 (offsets are 0).
* knn — per-device (B, k) frontiers are gathered with the same one-hot
  trick (``jnp.where``-gated, never multiplied: ``0 * inf`` is NaN) and
  merged by one two-key ``(d2, id)`` sort; the ``INT32_MAX`` sentinel maps
  to ``-1`` on the way out.
* aggregate — ``psum`` for counts/sums, ``pmin``/``pmax`` for the bbox.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core.types import EMPTY_RECT
from repro.kernels import ops
from repro.query.result import SpatialResult

QUERY_KINDS = ("ids", "knn", "radius", "aggregate")

DEFAULT_KCAP = 64

PAD_ROWS = {
    "ids": np.asarray(EMPTY_RECT, dtype=np.int32).reshape(4),
    "aggregate": np.asarray(EMPTY_RECT, dtype=np.int32).reshape(4),
    "knn": np.zeros(4, dtype=np.int32),
    "radius": np.array([0, 0, -1, 0], dtype=np.int32),
}


# ------------------------------------------------------------------ payloads

def pack_rects(rects: np.ndarray) -> np.ndarray:
    """ids/aggregate payload: the validated (Q, 4) rect batch itself."""
    return np.ascontiguousarray(rects, dtype=np.int32)


def pack_knn(points: np.ndarray) -> np.ndarray:
    """knn payload: (Q, 2) points widened to ``[x, y, 0, 0]`` rows."""
    q = points.shape[0]
    out = np.zeros((q, 4), dtype=np.int32)
    out[:, :2] = points
    return out


def pack_radius(points: np.ndarray, radii: np.ndarray) -> np.ndarray:
    """radius payload: ``[x, y, r, 0]`` rows."""
    q = points.shape[0]
    out = np.zeros((q, 4), dtype=np.int32)
    out[:, :2] = points
    out[:, 2] = radii
    return out


def payload_rects(kind: str, payload: np.ndarray) -> np.ndarray:
    """(Q, 4) rect view of a payload for Morton ordering — point kinds order
    by the degenerate ``[x, y, x, y]`` rect of the query point."""
    if kind in ("ids", "aggregate"):
        return payload
    return np.concatenate([payload[:, :2], payload[:, :2]], axis=1)


# ---------------------------------------------------------------- SPMD steps

def _flat_device_index(mesh: jax.sharding.Mesh) -> jnp.ndarray:
    """This device's row in axis-major flattened mesh order — the same order
    ``PartitionSpec(axes)`` assigns shards, so row ``d`` of a sharded operand
    lives on flat device ``d``."""
    idx = jnp.int32(0)
    for a in mesh.axis_names:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return idx


def _global_offsets(local_counts: jnp.ndarray, axes, didx: jnp.ndarray,
                    num_devices: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Exclusive cross-device offsets + totals without an all_gather.

    One-hot outer product then psum: every device ends up holding the full
    (D, B) count table, from which its own exclusive prefix (devices with a
    smaller flat index) and the batch totals are row sums.
    """
    dev = jnp.arange(num_devices, dtype=jnp.int32)
    sel = (dev == didx)[:, None]                       # (D, 1)
    table = jax.lax.psum(
        jnp.where(sel, local_counts[None, :], 0), axes)  # (D, B)
    base = jnp.sum(jnp.where(dev[:, None] < didx, table, 0),
                   axis=0).astype(jnp.int32)
    total = jnp.sum(table, axis=0).astype(jnp.int32)
    return base, total


def make_kind_step(
    mesh: jax.sharding.Mesh,
    kind: str,
    *,
    impl: str = ops.DEFAULT_IMPL,
    tq: int = 512,
    tr: int = 1024,
    kcap: int = DEFAULT_KCAP,
    k: int = 8,
    donate_payload: bool = True,
    on_trace: Callable[[], None] | None = None,
):
    """Build the jitted SPMD step for one query kind (see module docstring).

    Returns ``step(coords, ids, rect_tile_mbrs, cover_mbrs, payload)``
    whose outputs all carry the query axis first, so
    :func:`repro.core.engine.stream_batches` can concatenate them across
    micro-batches uniformly:

    =========  =====================================================
    ids        ``(slots_plus1 (B, kcap) i32, total (B,) i32)``
    radius     same as ids
    knn        ``(dists (B, k) f32, ids (B, k) i32, -1 empty)``
    aggregate  ``(counts (B,) i32, sums (B, 3) f32, bbox (B, 4) i32)``
    =========  =====================================================
    """
    if kind not in QUERY_KINDS:
        raise ValueError(f"unknown query kind {kind!r}; one of {QUERY_KINDS}")
    axes = tuple(mesh.axis_names)
    num_devices = int(np.prod([mesh.shape[a] for a in axes]))
    p_coords = jax.sharding.PartitionSpec(None, axes)
    p_meta = jax.sharding.PartitionSpec(axes)
    p_rep = jax.sharding.PartitionSpec()

    def shard_fn(local_coords, local_ids, local_rmbrs, local_cover, payload):
        if on_trace is not None:
            on_trace()
        cover = local_cover.reshape(-1, 4)
        rmbrs = local_rmbrs.reshape(-1, 4)
        rids = local_ids.reshape(-1)

        if kind == "ids":
            queries = payload
            if num_devices == 1:
                base = jnp.zeros((queries.shape[0],), jnp.int32)
                slots, total = ops.materialize_ids_fused(
                    queries, local_coords, rids, rmbrs, cover, base,
                    kcap=kcap, tq=tq, tr=tr, impl=impl)
                return slots, total
            local_counts = ops.overlap_counts_fused(
                queries, local_coords, rmbrs, cover,
                tq=tq, tr=tr, impl=impl)
            didx = _flat_device_index(mesh)
            base, total = _global_offsets(
                local_counts, axes, didx, num_devices)
            slots, _ = ops.materialize_ids_fused(
                queries, local_coords, rids, rmbrs, cover, base,
                kcap=kcap, tq=tq, tr=tr, impl=impl)
            return jax.lax.psum(slots, axes), total

        if kind == "radius":
            pts = payload[:, :2]
            rad = payload[:, 2]
            if num_devices == 1:
                base = jnp.zeros((pts.shape[0],), jnp.int32)
                slots, total = ops.materialize_radius_fused(
                    pts, rad, local_coords, rids, rmbrs, base,
                    kcap=kcap, tq=tq, tr=tr, impl=impl)
                return slots, total
            # pass 1: a kcap=1 scatter is the radius count kernel — the
            # slots output is discarded, only the counts channel is used
            _, local_counts = ops.materialize_radius_fused(
                pts, rad, local_coords, rids, rmbrs,
                jnp.zeros((pts.shape[0],), jnp.int32),
                kcap=1, tq=tq, tr=tr, impl=impl)
            didx = _flat_device_index(mesh)
            base, total = _global_offsets(
                local_counts, axes, didx, num_devices)
            slots, _ = ops.materialize_radius_fused(
                pts, rad, local_coords, rids, rmbrs, base,
                kcap=kcap, tq=tq, tr=tr, impl=impl)
            return jax.lax.psum(slots, axes), total

        if kind == "knn":
            pts = payload[:, :2]
            dists, idx = ops.knn_fused(
                pts, local_coords, rids, rmbrs,
                k=k, tq=tq, tr=tr, impl=impl)
            if num_devices > 1:
                didx = _flat_device_index(mesh)
                dev = jnp.arange(num_devices, dtype=jnp.int32)
                sel = (dev == didx)[:, None, None]           # (D, 1, 1)
                # jnp.where, never multiply: empty slots carry inf and
                # 0 * inf would poison the psum with NaNs
                gd = jax.lax.psum(
                    jnp.where(sel, dists[None], jnp.float32(0.0)), axes)
                gi = jax.lax.psum(jnp.where(sel, idx[None], 0), axes)
                b = pts.shape[0]
                dcat = jnp.moveaxis(gd, 0, 1).reshape(b, num_devices * k)
                icat = jnp.moveaxis(gi, 0, 1).reshape(b, num_devices * k)
                dists, idx = jax.lax.sort(
                    (dcat, icat), dimension=1, num_keys=2)
                dists, idx = dists[:, :k], idx[:, :k]
            idx = jnp.where(idx == ops.INT32_MAX, -1, idx)
            return dists, idx

        # aggregate
        queries = payload
        counts, sums, bbox = ops.aggregate_fused(
            queries, local_coords, rmbrs, cover, tq=tq, tr=tr, impl=impl)
        counts = jax.lax.psum(counts, axes)
        sums = jax.lax.psum(sums, axes)
        bbox_min = jax.lax.pmin(bbox[:2], axes)
        bbox_max = jax.lax.pmax(bbox[2:], axes)
        bbox = jnp.concatenate([bbox_min, bbox_max], axis=0)
        return counts, sums.T, bbox.T

    fn = compat.shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(p_coords, p_meta, p_meta, p_meta, p_rep),
        out_specs=(p_rep,) * (3 if kind == "aggregate" else 2),
        check_vma=False,  # Pallas calls don't carry varying-mesh-axis info
    )
    return jax.jit(fn, donate_argnums=(4,) if donate_payload else ())


# ------------------------------------------------------------------ assembly

def assemble(kind: str, out, *, kcap: int = DEFAULT_KCAP) -> SpatialResult:
    """Fold a streamed step output into a :class:`SpatialResult`.

    Decodes the plus-one slot encoding (0 → -1 empty) for the materializing
    kinds and computes overflow from the true totals; counts valid
    neighbors for knn; repacks the aggregate triple.
    """
    if kind in ("ids", "radius"):
        slots, total = out
        ids = np.asarray(slots, dtype=np.int32) - 1
        total = np.asarray(total, dtype=np.int32)
        overflow = np.maximum(total - kcap, 0).astype(np.int32)
        return SpatialResult(kind=kind, count=total, ids=ids,
                             overflow=overflow)
    if kind == "knn":
        dists, ids = out
        ids = np.asarray(ids, dtype=np.int32)
        count = (ids >= 0).sum(axis=1).astype(np.int32)
        return SpatialResult(kind="knn", count=count, ids=ids,
                             distances=np.asarray(dists, dtype=np.float32))
    counts, sums, bbox = out
    return SpatialResult(
        kind="aggregate", count=np.asarray(counts, dtype=np.int32),
        aggregates={"sums": np.asarray(sums, dtype=np.float32),
                    "bbox": np.asarray(bbox, dtype=np.int32)})
