"""Structured span tracer: nested spans, monotonic clocks, JSONL export.

Design constraints (DESIGN.md Sec 12):

* **~zero cost when disabled.**  ``span()`` on a disabled tracer returns one
  shared no-op context manager — no allocation, no clock read, no lock.  The
  hot path (``stream_batches``, the serving loop) calls ``span()``
  unconditionally and pays only an attribute check per call.
* **Monotonic timestamps.**  All times come from ``time.monotonic_ns`` —
  never the wall clock (pallint PL111 enforces the same rule on the hot-path
  modules this tracer instruments).
* **Thread-safe, per-thread nesting.**  The active-span stack is
  thread-local, so spans opened on the serving worker thread parent
  correctly within that thread and never cross-parent onto another thread's
  stack; the event buffer itself is shared under a lock.
* **JSON-lines export.**  One event per line, each a flat dict —
  ``{"id", "parent", "name", "phase", "t0_ns", "t1_ns", "thread", "attrs"}``
  — consumed by :mod:`repro.obs.phases` and ``python -m repro.obs.report``.
* **jax passthrough.**  With ``enable(annotate=True)`` every span also
  enters ``jax.profiler.TraceAnnotation`` (falling back to
  ``jax.named_scope``), so spans show up in a captured jax profile without a
  second instrumentation layer.  jax is imported lazily and only then.

Phases are plain strings (see :mod:`repro.obs.phases`); the tracer itself
has no opinion about them beyond recording the tag.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Callable, Iterator


class _NullSpan:
    """Shared no-op span handed out while the tracer is disabled."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs) -> None:
        """No-op attribute update (mirrors :meth:`Span.set`)."""


_NULL_SPAN = _NullSpan()


def _jax_annotation(name: str):
    """Best-available jax annotation context for ``name`` (lazy import)."""
    try:
        import jax
    except Exception:           # jax genuinely unavailable: annotate is a no-op
        return None
    profiler = getattr(jax, "profiler", None)
    ann = getattr(profiler, "TraceAnnotation", None) if profiler else None
    if ann is None:
        ann = getattr(jax, "named_scope", None)
    return ann(name) if ann is not None else None


class Span:
    """One open span: records ``[t0, t1]`` and its parent on exit."""

    __slots__ = ("_tracer", "name", "phase", "attrs", "id", "parent",
                 "t0_ns", "_ann")

    def __init__(self, tracer: "Tracer", name: str, phase: str,
                 attrs: dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.phase = phase
        self.attrs = attrs
        self.id = tracer._next_id()
        self.parent: int | None = None
        self.t0_ns = 0
        self._ann = None

    def set(self, **attrs) -> None:
        """Attach/overwrite attributes while the span is open."""
        self.attrs.update(attrs)

    def __enter__(self) -> "Span":
        stack = self._tracer._stack()
        self.parent = stack[-1] if stack else None
        stack.append(self.id)
        if self._tracer._annotate:
            self._ann = _jax_annotation(self.name)
            if self._ann is not None:
                self._ann.__enter__()
        self.t0_ns = time.monotonic_ns()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.monotonic_ns()
        if self._ann is not None:
            self._ann.__exit__(*exc)
            self._ann = None
        stack = self._tracer._stack()
        if stack and stack[-1] == self.id:
            stack.pop()
        self._tracer._record(self, t1)
        return False


class Tracer:
    """Thread-safe span recorder.  Disabled (and empty) until :meth:`enable`."""

    def __init__(self):
        self._lock = threading.Lock()
        self._local = threading.local()
        self._events: list[dict[str, Any]] = []
        self._id = 0
        self._enabled = False
        self._annotate = False

    # -- lifecycle ---------------------------------------------------------

    @property
    def enabled(self) -> bool:
        return self._enabled

    def enable(self, *, annotate: bool = False) -> None:
        """Start recording; ``annotate=True`` mirrors spans into jax."""
        self._annotate = bool(annotate)
        self._enabled = True

    def disable(self) -> None:
        self._enabled = False

    def reset(self) -> None:
        """Drop all recorded events (ids restart; open spans are orphaned)."""
        with self._lock:
            self._events.clear()
            self._id = 0

    # -- recording ---------------------------------------------------------

    def span(self, name: str, *, phase: str = "host", **attrs) -> Span | _NullSpan:
        """Open a span; returns the shared no-op span when disabled."""
        if not self._enabled:
            return _NULL_SPAN
        return Span(self, name, phase, attrs)

    def event(self, name: str, *, phase: str = "host", **attrs) -> None:
        """Record an instantaneous event (``t0 == t1``)."""
        if not self._enabled:
            return
        now = time.monotonic_ns()
        stack = self._stack()
        with self._lock:
            self._id += 1
            self._events.append({
                "id": self._id,
                "parent": stack[-1] if stack else None,
                "name": name, "phase": phase,
                "t0_ns": now, "t1_ns": now,
                "thread": threading.get_ident(),
                "attrs": attrs,
            })

    def record(self, name: str, *, phase: str, seconds: float,
               **attrs) -> None:
        """Record a synthesized span of a known duration ending now.

        For measurement harnesses (``phases.measure``) that time several
        repeats and want exactly one representative span in the trace —
        re-entering a live span per repeat would multiply the phase totals.
        """
        if not self._enabled:
            return
        t1 = time.monotonic_ns()
        t0 = t1 - max(0, int(seconds * 1e9))
        stack = self._stack()
        with self._lock:
            self._id += 1
            self._events.append({
                "id": self._id,
                "parent": stack[-1] if stack else None,
                "name": name, "phase": phase,
                "t0_ns": t0, "t1_ns": t1,
                "thread": threading.get_ident(),
                "attrs": attrs,
            })

    def _next_id(self) -> int:
        with self._lock:
            self._id += 1
            return self._id

    def _stack(self) -> list[int]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _record(self, span: Span, t1_ns: int) -> None:
        with self._lock:
            self._events.append({
                "id": span.id, "parent": span.parent,
                "name": span.name, "phase": span.phase,
                "t0_ns": span.t0_ns, "t1_ns": t1_ns,
                "thread": threading.get_ident(),
                "attrs": span.attrs,
            })

    # -- export ------------------------------------------------------------

    def events(self) -> list[dict[str, Any]]:
        """Snapshot of recorded events (shallow copies, safe to mutate)."""
        with self._lock:
            return [dict(e) for e in self._events]

    def to_jsonl(self) -> str:
        return "\n".join(json.dumps(e, sort_keys=True) for e in self.events())

    def export_jsonl(self, path: str) -> int:
        """Write one JSON event per line; returns the event count."""
        events = self.events()
        with open(path, "w", encoding="utf-8") as fh:
            for e in events:
                fh.write(json.dumps(e, sort_keys=True) + "\n")
        return len(events)


def load_jsonl(path: str) -> list[dict[str, Any]]:
    """Read events written by :meth:`Tracer.export_jsonl`."""
    out = []
    with open(path, "r", encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


# -- module-level default tracer (what the instrumented stack uses) ---------

_GLOBAL = Tracer()


def get_tracer() -> Tracer:
    return _GLOBAL


def enabled() -> bool:
    return _GLOBAL.enabled


def enable(*, annotate: bool = False) -> None:
    _GLOBAL.enable(annotate=annotate)


def disable() -> None:
    _GLOBAL.disable()


def reset() -> None:
    _GLOBAL.reset()


def span(name: str, *, phase: str = "host", **attrs) -> Span | _NullSpan:
    return _GLOBAL.span(name, phase=phase, **attrs)


def event(name: str, *, phase: str = "host", **attrs) -> None:
    _GLOBAL.event(name, phase=phase, **attrs)


def record(name: str, *, phase: str, seconds: float, **attrs) -> None:
    _GLOBAL.record(name, phase=phase, seconds=seconds, **attrs)
