"""Render a Fig-10-style phase breakdown from a trace file.

Usage::

    python -m repro.obs.report TRACE.jsonl [--json] [--top N]
    python -m repro.obs.report --selftest [--out DIR]
    python -m repro.obs.report --demo [--out DIR]

* With a trace file: prints the per-phase self-time table (seconds,
  fraction, bar) plus the top spans by self-time.
* ``--selftest``: builds a synthetic nested trace with known durations
  (stdlib only — no jax), checks the accounting invariants (phase seconds
  partition the wall time, fractions sum to 1, JSONL round-trips, the
  Prometheus exporter emits well-formed text), renders the table, and exits
  0/1.  CI runs this in the pallint job.
* ``--demo``: traces a tiny real engine run (build → placement → streamed
  queries → blocking Fig-10 slices) and renders its breakdown; with
  ``--out`` the trace JSONL and a metrics snapshot are written there (CI
  uploads these as tier-1 artifacts).

Exit status: 0 on success, 1 on a failed selftest or unreadable trace.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from repro.obs import metrics, phases, trace

_BAR_WIDTH = 30


def render_table(events, top: int = 5) -> str:
    """The human-readable breakdown: phase table + top self-time spans."""
    bd = phases.breakdown(events)
    lines = ["phase     seconds      fraction",
             "-----     -------      --------"]
    for p in phases.PHASES:
        s = bd["seconds"][p]
        f = bd["fractions"][p]
        bar = "#" * int(round(f * _BAR_WIDTH))
        lines.append(f"{p:<9} {s:>10.6f}   {f:>7.1%}  {bar}")
    lines.append(f"total     {sum(bd['seconds'].values()):>10.6f}   "
                 f"(wall {bd['wall_s']:.6f}s over {bd['spans']} spans)")
    if top > 0:
        self_s = _self_times(events)
        ranked = sorted(self_s.items(), key=lambda kv: -kv[1])[:top]
        if ranked:
            lines.append("")
            lines.append(f"top spans by self-time:")
            for (name, phase_tag), s in ranked:
                lines.append(f"  {s:>10.6f}s  [{phase_tag}] {name}")
    return "\n".join(lines)


def _self_times(events) -> dict[tuple[str, str], float]:
    child_ns: dict[int, int] = {}
    for e in events:
        p = e.get("parent")
        if p is not None:
            child_ns[p] = child_ns.get(p, 0) + (e["t1_ns"] - e["t0_ns"])
    out: dict[tuple[str, str], float] = {}
    for e in events:
        self_ns = max(0, (e["t1_ns"] - e["t0_ns"]) - child_ns.get(e["id"], 0))
        key = (e["name"], e.get("phase") or phases.HOST)
        out[key] = out.get(key, 0.0) + self_ns / 1e9
    return out


def _write_artifacts(out_dir: str, tracer: trace.Tracer,
                     registry: metrics.Registry) -> tuple[str, str]:
    os.makedirs(out_dir, exist_ok=True)
    trace_path = os.path.join(out_dir, "trace.jsonl")
    metrics_path = os.path.join(out_dir, "metrics.json")
    tracer.export_jsonl(trace_path)
    with open(metrics_path, "w", encoding="utf-8") as fh:
        fh.write(registry.snapshot_json() + "\n")
    return trace_path, metrics_path


# ---------------------------------------------------------------------------
# --selftest: synthetic trace, no jax
# ---------------------------------------------------------------------------


def _synthetic_trace(tracer: trace.Tracer) -> None:
    """A nested pipeline-shaped trace with real (tiny) monotonic durations."""
    with tracer.span("pipeline", phase=phases.HOST):
        with tracer.span("build_str_3level", phase=phases.BUILD):
            time.sleep(0.002)
        with tracer.span("place", phase=phases.H2D):
            time.sleep(0.001)
        for i in range(3):
            with tracer.span("stage", phase=phases.H2D, batch=i):
                time.sleep(0.0005)
            with tracer.span("dispatch", phase=phases.KERNEL, batch=i):
                time.sleep(0.002)
        with tracer.span("sync_retrieve", phase=phases.D2H):
            time.sleep(0.001)
        tracer.event("degrade", phase=phases.HOST, reason="selftest")


def _selftest(out_dir: str | None) -> int:
    tracer = trace.Tracer()
    tracer.enable()
    _synthetic_trace(tracer)
    tracer.disable()
    events = tracer.events()
    bd = phases.breakdown(events)
    failures = []
    # invariant 1: self-times partition the root wall time
    if abs(sum(bd["seconds"].values()) - bd["wall_s"]) > 1e-9 + 1e-6 * bd["wall_s"]:
        failures.append(
            f"phase seconds {sum(bd['seconds'].values()):.9f} != "
            f"wall {bd['wall_s']:.9f}")
    # invariant 2: fractions sum to 1 for a non-empty trace
    if abs(sum(bd["fractions"].values()) - 1.0) > 1e-9:
        failures.append("fractions do not sum to 1")
    # invariant 3: every slept phase is represented
    for p in (phases.BUILD, phases.H2D, phases.KERNEL, phases.D2H):
        if bd["seconds"][p] <= 0:
            failures.append(f"phase {p!r} recorded no time")
    # invariant 4: the kernel sleeps dominate this synthetic pipeline
    if bd["seconds"][phases.KERNEL] < bd["seconds"][phases.D2H]:
        failures.append("kernel phase did not dominate the synthetic trace")
    # invariant 5: JSONL round-trip is lossless
    reloaded = [json.loads(line) for line in tracer.to_jsonl().splitlines()]
    if reloaded != events:
        failures.append("JSONL round-trip mismatch")
    # invariant 6: the metrics exporters are well-formed
    reg = metrics.Registry()
    reg.counter("selftest_events_total", "selftest").inc(3, kind="dispatch")
    hist = reg.histogram("selftest_latency_seconds", "selftest")
    for v in (0.001, 0.002, 0.004, 0.2):
        hist.observe(v)
    text = reg.prometheus_text()
    if ('selftest_events_total{kind="dispatch"} 3' not in text
            or 'selftest_latency_seconds_bucket{le="+Inf"} 4' not in text):
        failures.append("prometheus exposition malformed:\n" + text)
    p50 = hist.percentile(50)
    if p50 is None or not (0.001 <= p50 <= 0.004):
        failures.append(f"histogram p50 estimate {p50} outside sample range")

    print(render_table(events))
    if out_dir:
        paths = _write_artifacts(out_dir, tracer, reg)
        print(f"wrote {paths[0]} and {paths[1]}")
    if failures:
        for f in failures:
            print(f"SELFTEST FAIL: {f}", file=sys.stderr)
        return 1
    print("selftest OK")
    return 0


# ---------------------------------------------------------------------------
# --demo: trace a tiny real engine run (needs jax)
# ---------------------------------------------------------------------------


def _demo(out_dir: str | None) -> int:
    import numpy as np

    from repro import compat
    from repro.core import engine as beng
    from repro.core import rtree
    from repro.data import datasets, spider

    tracer = trace.get_tracer()
    tracer.reset()
    tracer.enable()
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    rects = spider.uniform(4000, seed=11, max_size=0.02)
    queries = datasets.make_queries(rects, 0.5, seed=12)
    with tracer.span("demo", phase=phases.HOST):
        tree = rtree.build_str_3level(
            rects, *rtree.choose_parameters(len(rects), 1))
        eng = beng.BroadcastEngine(tree, mesh, batch_size=256)
        eng.query(queries)
        step = beng.make_query_step(mesh, donate_queries=False)
        batch = np.asarray(queries[:256], np.int32)
        phases.measure_query_phases(
            step, (eng.leaf_coords, eng.rect_tile_mbrs, eng.cover_mbrs),
            batch, eng._rep_sh, repeats=3)
    tracer.disable()
    events = tracer.events()
    print(render_table(events))
    if out_dir:
        reg = metrics.get_registry()
        paths = _write_artifacts(out_dir, tracer, reg)
        print(f"wrote {paths[0]} and {paths[1]}")
    return 0


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="repro.obs.report",
        description="render a Fig-10-style phase breakdown from a trace")
    parser.add_argument("trace", nargs="?", help="trace JSONL file")
    parser.add_argument("--json", action="store_true",
                        help="emit the breakdown as JSON instead of a table")
    parser.add_argument("--top", type=int, default=5,
                        help="how many top spans to list (0 disables)")
    parser.add_argument("--selftest", action="store_true",
                        help="validate the accounting on a synthetic trace")
    parser.add_argument("--demo", action="store_true",
                        help="trace a tiny real engine run (needs jax)")
    parser.add_argument("--out", metavar="DIR", default=None,
                        help="write trace.jsonl + metrics.json artifacts")
    args = parser.parse_args(argv)

    if args.selftest:
        return _selftest(args.out)
    if args.demo:
        return _demo(args.out)
    if not args.trace:
        parser.print_usage(sys.stderr)
        return 2
    try:
        events = trace.load_jsonl(args.trace)
    except (OSError, ValueError) as e:
        print(f"cannot read trace {args.trace!r}: {e}", file=sys.stderr)
        return 1
    if args.json:
        print(json.dumps(phases.breakdown(events), indent=2, sort_keys=True))
    else:
        print(render_table(events, top=args.top))
    return 0


if __name__ == "__main__":
    sys.exit(main())
