"""Metrics registry: counters, gauges, histograms; Prometheus + JSON export.

A deliberately small, dependency-free subset of the Prometheus client model:

* :class:`Counter` — monotonically increasing, with optional labels (one
  series per label combination).
* :class:`Gauge`   — settable value, with optional labels.
* :class:`Histogram` — **fixed bucket edges chosen at construction** (the
  low-overhead design: one array increment per observation, no per-sample
  storage).  Tracks count/sum/min/max plus per-bucket counts and supports
  quantile *estimates* via linear interpolation inside the covering bucket
  (:meth:`Histogram.percentile`).
* :class:`Registry` — get-or-create factory for the above, thread-safe,
  with two exporters: :meth:`Registry.prometheus_text` (Prometheus text
  exposition format 0.0.4) and :meth:`Registry.snapshot` (plain JSON dict,
  what the benchmarks persist next to their timing rows).

``get_registry()`` returns the process-default registry (used by the pallint
runtime guards); subsystems that want isolation (``SpatialServer``) create
their own ``Registry`` and expose it.  :func:`aggregate_prometheus` merges
many registries into one scrape surface, tagging each source's series with a
``replica=...`` label — the router's multi-replica endpoint.
"""
from __future__ import annotations

import json
import math
import re
import threading
from typing import Iterable, Mapping

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")

# Default latency buckets (seconds): log-ish spacing from 100µs to 60s,
# matching the serving layer's SLO range.  Sub-bucket percentile error is
# bounded by the bucket width at the quantile's magnitude (~2.5x here).
LATENCY_BUCKETS_S = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0,
)


def _label_key(labels: Mapping[str, str]) -> tuple[tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_str(key: tuple[tuple[str, str], ...]) -> str:
    if not key:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return "{" + inner + "}"


class Counter:
    """Monotonic counter family; one float series per label combination."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._series: dict[tuple[tuple[str, str], ...], float] = {}

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        key = _label_key(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        return self._series.get(_label_key(labels), 0.0)

    def total(self) -> float:
        with self._lock:
            return sum(self._series.values())

    def as_dict(self, label: str) -> dict[str, float]:
        """``{label_value: count}`` for a single-label family (e.g. the
        serving loop's event counters keyed by ``kind``)."""
        out: dict[str, float] = {}
        with self._lock:
            for key, v in self._series.items():
                d = dict(key)
                if label in d:
                    out[d[label]] = out.get(d[label], 0.0) + v
        return out

    def series(self) -> dict[tuple[tuple[str, str], ...], float]:
        with self._lock:
            return dict(self._series)


class Gauge:
    """Settable value family."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._series: dict[tuple[tuple[str, str], ...], float] = {}

    def set(self, value: float, **labels) -> None:
        with self._lock:
            self._series[_label_key(labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        key = _label_key(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        return self._series.get(_label_key(labels), 0.0)

    def series(self) -> dict[tuple[tuple[str, str], ...], float]:
        with self._lock:
            return dict(self._series)


class Histogram:
    """Fixed-bucket histogram (no labels; one instrument per series).

    ``buckets`` are the **upper** edges of the first ``len(buckets)``
    buckets; an implicit overflow bucket (``+Inf``) catches the rest.  An
    observation lands in the first bucket whose edge is ``>= x``.

    :meth:`percentile` returns an interpolated estimate: the covering bucket
    is located from cumulative counts and the quantile is placed linearly
    within it, with the first bucket floored at the observed minimum and the
    overflow bucket capped at the observed maximum.  The estimate is exact
    at bucket edges and off by at most one bucket width elsewhere — the
    window is *all observations since construction* (cumulative, Prometheus
    semantics), not a sliding window.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Iterable[float] = LATENCY_BUCKETS_S):
        self.name = name
        self.help = help
        edges = tuple(float(b) for b in buckets)
        if not edges or any(nxt <= prev for nxt, prev in zip(edges[1:], edges)):
            raise ValueError(f"histogram {name}: bucket edges must be "
                             "non-empty and strictly increasing")
        self.edges = edges
        self._lock = threading.Lock()
        self._counts = [0] * (len(edges) + 1)    # +1 = overflow (+Inf)
        self._sum = 0.0
        self._count = 0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, x: float) -> None:
        x = float(x)
        i = 0
        for i, edge in enumerate(self.edges):
            if x <= edge:
                break
        else:
            i = len(self.edges)
        with self._lock:
            self._counts[i] += 1
            self._sum += x
            self._count += 1
            self._min = min(self._min, x)
            self._max = max(self._max, x)

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def mean(self) -> float | None:
        return self._sum / self._count if self._count else None

    def percentile(self, q: float) -> float | None:
        """Interpolated quantile estimate in ``[0, 100]``; None when empty."""
        if not 0 <= q <= 100:
            raise ValueError(f"percentile {q} outside [0, 100]")
        with self._lock:
            total = self._count
            if total == 0:
                return None
            counts = list(self._counts)
            lo_obs, hi_obs = self._min, self._max
        target = q / 100.0 * total
        cum = 0
        for i, c in enumerate(counts):
            if cum + c >= target and c > 0:
                lo = lo_obs if i == 0 else self.edges[i - 1]
                hi = hi_obs if i == len(self.edges) else self.edges[i]
                lo = max(lo, lo_obs) if i == 0 else lo
                hi = min(hi, hi_obs)
                if hi < lo:
                    hi = lo
                frac = (target - cum) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
            cum += c
        return hi_obs

    def bucket_counts(self) -> list[tuple[float, int]]:
        """Cumulative ``(upper_edge, count)`` pairs, Prometheus-style."""
        with self._lock:
            counts = list(self._counts)
        out, cum = [], 0
        for edge, c in zip(self.edges, counts):
            cum += c
            out.append((edge, cum))
        out.append((math.inf, cum + counts[-1]))
        return out


class Registry:
    """Get-or-create instrument factory with JSON + Prometheus exporters."""

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: dict[str, object] = {}

    def _get_or_create(self, cls, name: str, help: str, **kw):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = cls(name, help, **kw)
                self._instruments[name] = inst
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(inst).__name__}, requested {cls.__name__}")
            return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Iterable[float] = LATENCY_BUCKETS_S) -> Histogram:
        return self._get_or_create(Histogram, name, help, buckets=buckets)

    def instruments(self) -> dict[str, object]:
        with self._lock:
            return dict(self._instruments)

    def reset(self) -> None:
        with self._lock:
            self._instruments.clear()

    # -- exporters ---------------------------------------------------------

    def snapshot(self) -> dict:
        """Plain-JSON snapshot of every instrument (what benchmarks persist)."""
        out: dict[str, dict] = {}
        for name, inst in sorted(self.instruments().items()):
            if isinstance(inst, (Counter, Gauge)):
                series = {(_label_str(k) or "__total__"): v
                          for k, v in inst.series().items()}
                out[name] = {"kind": inst.kind, "series": series}
            else:
                assert isinstance(inst, Histogram)
                out[name] = {
                    "kind": inst.kind,
                    "count": inst.count, "sum": inst.sum,
                    "buckets": [[e if math.isfinite(e) else "+Inf", c]
                                for e, c in inst.bucket_counts()],
                    "p50": inst.percentile(50),
                    "p90": inst.percentile(90),
                    "p99": inst.percentile(99),
                }
        return out

    def snapshot_json(self) -> str:
        return json.dumps(self.snapshot(), indent=2, sort_keys=True)

    def prometheus_text(self) -> str:
        """Prometheus text exposition format 0.0.4."""
        lines: list[str] = []
        for name, inst in sorted(self.instruments().items()):
            lines.extend(_render_header(name, inst))
            lines.extend(_render_series(name, inst, ()))
        return "\n".join(lines) + ("\n" if lines else "")


def _render_header(name: str, inst) -> list[str]:
    lines = []
    if inst.help:
        lines.append(f"# HELP {name} {inst.help}")
    lines.append(f"# TYPE {name} {inst.kind}")
    return lines


def _render_series(name: str, inst, extra: tuple[tuple[str, str], ...]
                   ) -> list[str]:
    """Sample lines for one instrument, with ``extra`` label pairs merged
    into every series (how aggregation tags per-replica registries)."""
    lines: list[str] = []
    if isinstance(inst, (Counter, Gauge)):
        series = inst.series() or {(): 0.0}
        for key in sorted(series):
            merged = tuple(sorted(extra + key))
            lines.append(f"{name}{_label_str(merged)} "
                         f"{_format(series[key])}")
    else:
        assert isinstance(inst, Histogram)
        for edge, cum in inst.bucket_counts():
            le = "+Inf" if math.isinf(edge) else _format(edge)
            merged = tuple(sorted((("le", le),) + extra))
            lines.append(f"{name}_bucket{_label_str(merged)} {cum}")
        lines.append(f"{name}_sum{_label_str(extra)} {_format(inst.sum)}")
        lines.append(f"{name}_count{_label_str(extra)} {inst.count}")
    return lines


def aggregate_prometheus(
    named: Mapping[str, Registry],
    *,
    label: str = "replica",
    base: Registry | None = None,
) -> str:
    """One Prometheus surface over many registries (the router's scrape
    endpoint: per-replica server registries + the router's own).

    Every series from ``named[name]`` is tagged ``{label}="name"``; series
    from ``base`` (if given) stay unlabeled.  Instruments sharing a metric
    name across sources are merged under one HELP/TYPE block (exposition
    format requires each name to appear exactly once), with the first
    non-empty help string winning."""
    groups: dict[str, list[tuple[tuple[tuple[str, str], ...], object]]] = {}
    if base is not None:
        for name, inst in sorted(base.instruments().items()):
            groups.setdefault(name, []).append(((), inst))
    for src in sorted(named):
        extra = ((label, str(src)),)
        for name, inst in sorted(named[src].instruments().items()):
            groups.setdefault(name, []).append((extra, inst))
    lines: list[str] = []
    for name in sorted(groups):
        entries = groups[name]
        kinds = {inst.kind for _, inst in entries}
        if len(kinds) > 1:
            raise TypeError(f"metric {name!r} registered with conflicting "
                            f"kinds across sources: {sorted(kinds)}")
        head = next((inst for _, inst in entries if inst.help), entries[0][1])
        lines.extend(_render_header(name, head))
        for extra, inst in entries:
            lines.extend(_render_series(name, inst, extra))
    return "\n".join(lines) + ("\n" if lines else "")


def _format(v: float) -> str:
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


_DEFAULT = Registry()


def get_registry() -> Registry:
    """The process-default registry (pallint guards export into this one)."""
    return _DEFAULT
