"""repro.obs — unified tracing + metrics for the kernel-vs-communication story.

The paper's entire argument decomposes execution into CPU build, CPU→DPU
transfer, kernel, and retrieve phases (Fig 10); PrIM-style benchmarking shows
PIM claims die without first-class phase instrumentation.  This package is
that layer for the whole repro stack:

* :mod:`repro.obs.trace`   — structured span tracer: nested spans with
  monotonic timestamps, thread-safe, ~zero cost when disabled, JSON-lines
  export, optional ``jax.profiler``/``named_scope`` annotation passthrough.
* :mod:`repro.obs.metrics` — metrics registry: counters, gauges, histograms
  with fixed bucket edges; Prometheus-text and JSON snapshot exporters.
* :mod:`repro.obs.phases`  — the phase accounting model: every span is tagged
  build / h2d / kernel / d2h / host, so any traced run can emit the paper's
  Fig-10-style breakdown plus derived bytes-moved and ops/byte from layout
  sizes.
* :mod:`repro.obs.report`  — ``python -m repro.obs.report trace.jsonl``
  renders the breakdown table; ``--selftest`` validates the accounting
  end-to-end without jax; ``--demo`` traces a tiny real engine run.

Instrumented producers: ``rtree.build_str_3level`` and
``engine.shard_tree``/``subtree.build_layout`` (build), engine placement
(h2d), ``engine.stream_batches`` (per-batch stage/dispatch/sync),
``SpatialServer`` (queue wait, batch formation, fast-path stage/step/
retrieve, degrade/recover transitions), and the pallint runtime guards
(recompile / implicit-transfer counts become exported metrics).
"""
from repro.obs import metrics, phases, trace  # noqa: F401
from repro.obs.metrics import Registry, get_registry  # noqa: F401
from repro.obs.trace import (  # noqa: F401
    Tracer, disable, enable, event, get_tracer, span)
