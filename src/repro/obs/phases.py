"""Phase accounting: the paper's Fig-10 decomposition as a first-class model.

Every span in a trace carries one of five phase tags:

=========  =================================================================
``build``  host-side index construction (STR pack, shard_tree, build_layout)
``h2d``    host→device movement (placement scatter/broadcast, batch staging)
``kernel`` device compute (the fused two-phase query kernel)
``d2h``    device→host movement (count retrieval, the end-of-set sync)
``host``   everything else on the host (padding, batch formation, queueing)
=========  =================================================================

:func:`breakdown` folds a trace into per-phase **self-time** — each span is
charged its duration minus its children's, so nested spans partition instead
of double-counting and the per-phase seconds sum exactly to the root spans'
wall time.  That identity is the subsystem's core invariant (tested in
``tests/test_obs.py``) and is what makes "communication must not dominate"
a checkable number instead of a paper claim.

:func:`measure` / :func:`measure_query_phases` are the *blocking* measurement
harnesses the benchmarks share: the pipelined hot path hides kernel latency
behind the end-of-set sync (by design — its dispatch spans measure host cost
only), so Fig-10-style kernel/transfer slices are taken by staging one batch
and synchronizing each slice explicitly, medians over repeats, recorded into
the trace as single synthesized spans.

:func:`derived_stats` turns a ``ShardedLayout``/``SubtreeLayout`` into the
bytes-moved and ops/byte numbers of the paper's Table IV accounting.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Mapping, Sequence

from repro.obs import trace

BUILD = "build"
H2D = "h2d"
KERNEL = "kernel"
D2H = "d2h"
HOST = "host"

PHASES = (BUILD, H2D, KERNEL, D2H, HOST)

# 8 integer ops per (query, rect) overlap test: 4 compares + 3 ands + 1 add.
OPS_PER_RECT_TEST = 8


def breakdown(events: Sequence[Mapping[str, Any]]) -> dict:
    """Fold trace events into per-phase self-time seconds and fractions.

    Returns ``{"seconds": {phase: s}, "fractions": {phase: f},
    "wall_s": float, "spans": int}`` where ``wall_s`` is the summed duration
    of root spans (spans with no parent) and ``sum(seconds.values()) ==
    wall_s`` up to float rounding — self-times partition the roots exactly.
    Unknown phase tags are folded into ``host``.
    """
    dur_ns: dict[int, int] = {}
    phase: dict[int, str] = {}
    child_ns: dict[int, int] = {}
    wall_ns = 0
    for e in events:
        d = max(0, int(e["t1_ns"]) - int(e["t0_ns"]))
        dur_ns[e["id"]] = d
        phase[e["id"]] = e.get("phase") or HOST
        parent = e.get("parent")
        if parent is None:
            wall_ns += d
        else:
            child_ns[parent] = child_ns.get(parent, 0) + d
    seconds = {p: 0.0 for p in PHASES}
    for eid, d in dur_ns.items():
        self_ns = d - child_ns.get(eid, 0)
        # a parent whose children overlap it awkwardly (cross-thread) never
        # goes negative; clamp so the partition stays a partition
        p = phase[eid]
        if p not in seconds:
            p = HOST
        seconds[p] += max(0, self_ns) / 1e9
    total = sum(seconds.values())
    fractions = {p: (s / total if total > 0 else 0.0)
                 for p, s in seconds.items()}
    return {"seconds": seconds, "fractions": fractions,
            "wall_s": wall_ns / 1e9, "spans": len(dur_ns)}


def span_seconds(events: Sequence[Mapping[str, Any]], name: str) -> float:
    """Summed duration of every span named ``name`` (0.0 when absent)."""
    total = 0
    for e in events:
        if e.get("name") == name:
            total += max(0, int(e["t1_ns"]) - int(e["t0_ns"]))
    return total / 1e9


def compose_pipeline(*, build_s: float, place_s: float,
                     per_batch: Mapping[str, float], num_batches: int,
                     stream_wall_s: float) -> dict:
    """Fold one-time and per-batch phase slices into end-to-end fractions.

    ``per_batch`` carries the blocking Fig-10 slices (``h2d_s``,
    ``kernel_s``, ``d2h_s`` from :func:`measure_query_phases`);
    ``stream_wall_s`` is the measured wall time of the real pipelined run
    over ``num_batches`` batches.  Whatever the pipelined run spent beyond
    the per-batch device slices is charged to ``host`` (batch formation,
    padding, dispatch overhead) — it can reach zero when pipelining
    perfectly overlaps staging with compute.
    """
    nb = int(num_batches)
    h2d = place_s + nb * per_batch["h2d_s"]
    kernel = nb * per_batch["kernel_s"]
    d2h = nb * per_batch["d2h_s"]
    host = max(0.0, stream_wall_s - nb * (per_batch["h2d_s"]
                                          + per_batch["kernel_s"]
                                          + per_batch["d2h_s"]))
    seconds = {BUILD: build_s, H2D: h2d, KERNEL: kernel, D2H: d2h,
               HOST: host}
    total = sum(seconds.values())
    return {
        "seconds": seconds,
        "fractions": {p: (s / total if total > 0 else 0.0)
                      for p, s in seconds.items()},
        "num_batches": nb,
        "stream_wall_s": stream_wall_s,
    }


def derived_stats(layout, num_queries: int, batch_size: int) -> dict:
    """Bytes-moved and arithmetic-intensity accounting from a layout.

    Works for both ``ShardedLayout`` (broadcast) and ``SubtreeLayout``
    via duck typing.  The kernel streams every device's local rect slice
    once per query batch (DESIGN.md Sec 6), so bytes-read and rect-test
    counts are closed-form in the layout — the same accounting the paper
    extracts from DPU counters for Table IV.
    """
    nq = int(num_queries)
    bs = int(batch_size)
    nb = -(-nq // bs) if bs else 0
    if hasattr(layout, "leaf_rects_flat"):          # ShardedLayout
        scatter = int(layout.leaf_bytes) + int(layout.metadata_bytes)
        broadcast = int(layout.cover_mbrs.nbytes)
        rects_per_device = int(layout.rects_per_device)
        num_devices = int(layout.num_devices)
    else:                                           # SubtreeLayout
        scatter = int(layout.scatter_bytes)
        broadcast = int(layout.root_mbrs.nbytes)
        rects_per_device = int(layout.rects.shape[1])
        num_devices = int(layout.num_devices)
    query_bytes = nb * bs * 16
    result_bytes = nq * 4
    h2d_bytes = scatter + broadcast + query_bytes
    kernel_bytes_read = nb * num_devices * rects_per_device * 16
    rect_tests = nq * rects_per_device * num_devices
    ops = rect_tests * OPS_PER_RECT_TEST
    streamed = kernel_bytes_read + h2d_bytes + result_bytes
    return {
        "h2d_bytes": h2d_bytes,
        "d2h_bytes": result_bytes,
        "placement_bytes": scatter + broadcast,
        "query_bytes": query_bytes,
        "kernel_bytes_read": kernel_bytes_read,
        "rect_tests": rect_tests,
        "ops": ops,
        "ops_per_transferred_byte": (
            ops / (h2d_bytes + result_bytes) if nq else 0.0),
        "ops_per_streamed_byte": ops / streamed if nq else 0.0,
    }


def measure(fn: Callable[[], Any], *, name: str, phase: str = KERNEL,
            repeats: int = 3, warmup: int = 1, **attrs) -> float:
    """Median blocking wall time of ``fn()`` in seconds, recorded as one
    synthesized span — the shared timing primitive of every benchmark.

    Blocks on jax outputs so device work is inside the measurement (this is
    a measurement harness, not the hot path — the sync is the point).
    """
    import jax

    for _ in range(warmup):
        out = fn()
        jax.block_until_ready(out)    # pallint: disable=PL102
    times = []
    for _ in range(int(repeats)):
        t0 = time.monotonic_ns()
        out = fn()
        jax.block_until_ready(out)    # pallint: disable=PL102
        times.append(time.monotonic_ns() - t0)
    med = sorted(times)[len(times) // 2] / 1e9
    trace.record(name, phase=phase, seconds=med, repeats=repeats, **attrs)
    return med


def measure_query_phases(step, operands, batch, rep_sharding, *,
                         repeats: int = 3, warmup: int = 1) -> dict:
    """Blocking per-batch Fig-10 slices for one engine step.

    Stages ``batch`` (H2D, synced), runs ``step`` (kernel, synced), and
    retrieves the counts (D2H) — each slice timed separately, medians over
    ``repeats``, recorded as three synthesized spans.  ``step`` must be a
    *non-donating* step (the staged buffer is reused across repeats); see
    ``benchmarks/common.bench_step``.
    """
    import jax

    h2d, kern, d2h = [], [], []
    for _ in range(warmup):
        staged = jax.device_put(batch, rep_sharding)
        jax.block_until_ready(step(*operands, staged))  # pallint: disable=PL102
    for _ in range(int(repeats)):
        t0 = time.monotonic_ns()
        staged = jax.device_put(batch, rep_sharding)
        jax.block_until_ready(staged)                   # pallint: disable=PL102
        t1 = time.monotonic_ns()
        out = step(*operands, staged)
        jax.block_until_ready(out)                      # pallint: disable=PL102
        t2 = time.monotonic_ns()
        jax.device_get(out)
        t3 = time.monotonic_ns()
        h2d.append(t1 - t0)
        kern.append(t2 - t1)
        d2h.append(t3 - t2)

    def _med(xs):
        return sorted(xs)[len(xs) // 2] / 1e9

    slices = {"h2d_s": _med(h2d), "kernel_s": _med(kern),
              "d2h_s": _med(d2h)}
    nbytes = int(getattr(batch, "nbytes", 0))
    trace.record("batch_stage", phase=H2D, seconds=slices["h2d_s"],
                 bytes=nbytes)
    trace.record("batch_kernel", phase=KERNEL, seconds=slices["kernel_s"],
                 batch=int(batch.shape[0]))
    trace.record("batch_retrieve", phase=D2H, seconds=slices["d2h_s"],
                 bytes=int(batch.shape[0]) * 4)
    return slices
