"""Serving launcher: batched greedy generation with the decode substrate.

    python -m repro.launch.serve --arch qwen2-1.5b --smoke --steps 32
"""
from __future__ import annotations

import argparse

import numpy as np

import jax

from repro import configs
from repro.launch import mesh as meshmod
from repro.models import api
from repro.serve import serve_loop


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    mesh = meshmod.single_device_mesh() if jax.device_count() == 1 \
        else meshmod.make_production_mesh()
    params = api.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(3, cfg.vocab, (args.batch, args.prompt_len))
    out = serve_loop.greedy_generate(
        cfg, params, prompts.astype(np.int32), args.steps, mesh=mesh,
        max_seq=args.max_seq)
    print(f"generated {out.shape[1] - args.prompt_len} tokens per request "
          f"for {args.batch} requests")
    print("first continuation:", out[0, args.prompt_len:].tolist()[:16])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
