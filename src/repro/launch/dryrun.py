"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) cell on 512 placeholder CPU devices.

The two ``os.environ`` lines below are the FIRST executable statements —
before any other import — because jax locks the device count at first
initialisation.

Per cell this script:
  1. builds the jitted step (train_step / prefill forward / serve decode
     step / spatial query step) with production shardings,
  2. ``.lower()``s it with ShapeDtypeStruct stand-ins (no allocation),
  3. ``.compile()``s it — proving the sharding config is coherent,
  4. prints ``compiled.memory_analysis()`` (fits per device) and
     ``cost_analysis()`` (FLOPs/bytes for §Roofline), and
  5. writes a JSON CellReport for the roofline/benchmark tooling.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
  python -m repro.launch.dryrun --spatial rtree_lakes --mesh single
"""
from __future__ import annotations

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import math
import time
import traceback

import numpy as np

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs import rtree_paper
from repro.core import engine as spatial_engine
from repro.core import rtree
from repro.launch import analysis
from repro.launch.mesh import make_production_mesh
from repro.models import api
from repro.models.base import ModelConfig
from repro.parallel.sharding import param_shardings, use_mesh
from repro.serve import serve_loop
from repro.train import train_loop
from repro.train.optimizer import AdamW


def _mesh_name(mesh) -> str:
    return "x".join(str(mesh.shape[a]) for a in mesh.axis_names)


def _abstract_opt_state(p_shapes):
    f32 = lambda t: jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), t)
    return {"m": f32(p_shapes), "v": f32(p_shapes),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def _lower_for_cfg(cfg: ModelConfig, shape_name: str, mesh):
    seq, gbatch, kind = configs.SHAPES[shape_name]
    with use_mesh(mesh):
        if kind == "train":
            opt = AdamW()
            step, p_shapes, _ = train_loop.make_train_step(
                cfg, mesh, opt, donate=True)
            batch_shapes = api.train_batch_shapes(cfg, gbatch, seq)
            return step.lower(
                p_shapes, _abstract_opt_state(p_shapes), batch_shapes)
        if kind == "prefill":
            step, p_shapes, batch_shapes = serve_loop.make_prefill_step(
                cfg, mesh, gbatch, seq)
            return step.lower(p_shapes, batch_shapes)
        # decode: one new token against a seq_len cache
        step, p_shapes, st_shapes, batch_shapes = \
            serve_loop.make_decode_step(cfg, mesh, gbatch, seq)
        return step.lower(p_shapes, st_shapes, batch_shapes)


def lower_cell(arch: str, shape_name: str, mesh) -> tuple:
    """Returns (lowered, kind, model_flops)."""
    cfg = configs.get_config(arch)
    seq, gbatch, kind = configs.SHAPES[shape_name]
    n_active = cfg.active_param_count()
    lowered = _lower_for_cfg(cfg, shape_name, mesh)
    if kind == "train":
        model_flops = 6.0 * n_active * gbatch * seq
    elif kind == "prefill":
        model_flops = 2.0 * n_active * gbatch * seq
    else:
        model_flops = 2.0 * n_active * gbatch  # one token per sequence
    return lowered, kind, model_flops


# ---------------------------------------------------------------------------
# Probe-corrected costs.
#
# XLA's HloCostAnalysis counts a while-loop body ONCE, so flops/bytes/
# collectives inside the scan-over-layers are undercounted by ~n_layers.  We
# therefore compile two small UNROLLED probes (k and 2k layers, identical
# global shapes/mesh) and reconstruct the true per-layer cost linearly:
#     f(k) = f_outside + k · f_layer  →  f(L) = f_outside + L · f_layer.
# The full scanned compile remains the memory/compile-coherence proof.
# ---------------------------------------------------------------------------


def _probe_ks(cfg: ModelConfig) -> tuple[int, int]:
    if cfg.family == "hybrid":
        u = len(cfg.block_pattern)
        return u, 2 * u
    return 2, 4


def _probe_cfg(cfg: ModelConfig, k: int) -> ModelConfig:
    import dataclasses as dc
    kw = {"n_layers": k, "scan_layers": False}
    if cfg.family == "encdec":
        kw["enc_layers"] = k
    return dc.replace(cfg, **kw)


def _cost_vector(compiled, chips: int) -> dict:
    s = analysis.analyze_compiled(compiled, chips=chips)
    vec = {"flops": s["flops_per_device"], "bytes": s["bytes_per_device"]}
    for k_, v in s["collective_per_device"].items():
        vec[f"coll:{k_}"] = float(v)
    return vec


def probe_corrected_costs(arch: str, shape_name: str, mesh,
                          chips: int) -> dict | None:
    cfg = configs.get_config(arch)
    k1, k2 = _probe_ks(cfg)
    if cfg.n_layers <= k2:   # tiny model: no correction needed
        return None
    try:
        v1 = _cost_vector(
            _lower_for_cfg(_probe_cfg(cfg, k1), shape_name, mesh).compile(),
            chips)
        v2 = _cost_vector(
            _lower_for_cfg(_probe_cfg(cfg, k2), shape_name, mesh).compile(),
            chips)
    except Exception:
        traceback.print_exc()
        return None
    out = {}
    l_full = cfg.n_layers
    for key in v1:
        per_layer = (v2[key] - v1.get(key, 0.0)) / (k2 - k1)
        f_out = v1[key] - k1 * per_layer
        out[key] = max(f_out + l_full * per_layer, v1[key])
    return out


def lower_spatial(name: str, mesh, batch: int = 10_000) -> tuple:
    """Spatial-engine dry-run: leaf arrays as ShapeDtypeStructs, production
    sharding, one query batch."""
    sc = rtree_paper.get_spatial_config(name)
    d = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    n = sc.num_rects
    b, f = (sc.leaf_capacity, sc.fanout)
    if not b:
        b, f = rtree.choose_parameters(n, d)
    leaves = math.ceil(n / b)
    lp = math.ceil(leaves / d)
    kmax = min(math.ceil(leaves / f), lp // f + 2)
    tr = sc.kernel_tr
    rp = math.ceil(lp * b / tr) * tr      # per-device slice, tile-padded
    coords_sds = jax.ShapeDtypeStruct((4, d * rp), jnp.int32)
    rmbr_sds = jax.ShapeDtypeStruct((d, rp // tr, 4), jnp.int32)
    cover_sds = jax.ShapeDtypeStruct((d, max(kmax, 1), 4), jnp.int32)
    q_sds = jax.ShapeDtypeStruct((batch, 4), jnp.int32)

    with use_mesh(mesh):
        step = spatial_engine.make_query_step(
            mesh, impl="xla", tq=sc.kernel_tq, tr=tr)
        lowered = step.lower(coords_sds, rmbr_sds, cover_sds, q_sds)
    # "useful work" for the spatial engine: one int comparison quadruple per
    # (query, local rect) — the two-phase filter makes most of it skippable,
    # so model_flops is the post-filter lower bound ≈ batch × N × selectivity.
    model_flops = 8.0 * batch * n * 0.01
    return lowered, "spatial", model_flops


def run_cell(arch: str, shape_name: str, mesh, out_dir: str | None,
             verbose: bool = True, probe: bool = True) -> analysis.CellReport:
    t0 = time.time()
    if arch.startswith("rtree_"):
        lowered, kind, model_flops = lower_spatial(arch, mesh)
    else:
        lowered, kind, model_flops = lower_cell(arch, shape_name, mesh)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    chips = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    stats = analysis.analyze_compiled(compiled, chips=chips)
    raw_flops = stats["flops_per_device"]
    notes = f"lower={t_lower:.1f}s compile={t_compile:.1f}s"

    if probe and not arch.startswith("rtree_"):
        corrected = probe_corrected_costs(arch, shape_name, mesh, chips)
        if corrected:
            stats["flops_per_device"] = corrected["flops"]
            stats["bytes_per_device"] = corrected["bytes"]
            stats["collective_per_device"] = {
                k_[len("coll:"):]: v for k_, v in corrected.items()
                if k_.startswith("coll:")}
            notes += (f" raw_scan_flops={raw_flops:.3e}"
                      " (costs probe-corrected for scan trip counts)")

    report = analysis.CellReport(
        arch=arch, shape=shape_name, mesh=_mesh_name(mesh), chips=chips,
        kind=kind, model_flops=model_flops, notes=notes,
        **stats)
    if verbose:
        mem = compiled.memory_analysis()
        print(f"--- {arch} × {shape_name} × mesh {report.mesh} ---")
        print(f"  memory_analysis: {mem}")
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        print(f"  cost_analysis: flops={ca.get('flops', 0):.3e} "
              f"bytes={ca.get('bytes accessed', 0):.3e}")
        print(f"  collectives/device: {report.collective_per_device}")
        print(f"  roofline: compute={report.compute_s:.3e}s "
              f"memory={report.memory_s:.3e}s "
              f"collective={report.collective_s:.3e}s "
              f"dominant={report.dominant} "
              f"useful_ratio={report.useful_flops_ratio:.3f}")
        print(f"  ({report.notes})")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fn = f"{arch}__{shape_name}__{report.mesh}.json".replace("/", "_")
        analysis.save_report(os.path.join(out_dir, fn), report)
    return report


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", help="architecture id or rtree_* spatial id")
    ap.add_argument("--shape", default="train_4k",
                    choices=list(configs.SHAPES) + ["spatial"])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true",
                    help="run every (arch × shape) cell + spatial cells")
    ap.add_argument("--spatial", action="store_true",
                    help="with --all: include rtree_* cells")
    ap.add_argument("--out", default=None, help="JSON report directory")
    ap.add_argument("--continue-on-error", action="store_true")
    ap.add_argument("--no-probe", action="store_true",
                    help="skip the cost-correction probe compiles (the "
                         "multi-pod pass proves sharding; §Roofline is "
                         "single-pod only)")
    ap.add_argument("--skip-existing", action="store_true",
                    help="skip cells whose JSON report already exists")
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(make_production_mesh(multi_pod=False))
    if args.mesh in ("multi", "both"):
        meshes.append(make_production_mesh(multi_pod=True))

    cells: list[tuple[str, str]] = []
    if args.all:
        cells = configs.all_cells()
        cells += [(n, "spatial") for n in rtree_paper.SPATIAL_CONFIGS]
    elif args.arch:
        if args.arch.startswith("rtree_"):
            cells = [(args.arch, "spatial")]
        else:
            cells = [(args.arch, args.shape)]
    else:
        ap.error("need --arch or --all")

    failures = []
    for mesh in meshes:
        for arch, shape in cells:
            if args.skip_existing and args.out:
                fn = (f"{arch}__{shape}__{_mesh_name(mesh)}.json"
                      .replace("/", "_"))
                if os.path.exists(os.path.join(args.out, fn)):
                    continue
            try:
                run_cell(arch, shape, mesh, args.out,
                         probe=not args.no_probe)
            except Exception:
                traceback.print_exc()
                failures.append((arch, shape, _mesh_name(mesh)))
                if not args.continue_on_error:
                    return 1
    if failures:
        print(f"FAILED cells: {failures}")
        return 1
    print(f"dry-run OK: {len(cells)} cells × {len(meshes)} meshes")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
