"""Compiled-artifact analysis: roofline terms from the dry-run.

The container is CPU-only, so roofline terms are *derived* from the compiled
SPMD module rather than measured:

    compute term    = HLO_FLOPs_total / (chips × peak_FLOP/s)
    memory term     = HLO_bytes_total / (chips × HBM_bw)
    collective term = collective_bytes_total / (chips × link_bw)

``compiled.cost_analysis()`` reports the per-partition program (one device's
work); totals multiply by the device count.  collective bytes are parsed from
``compiled.as_text()``: per collective op we charge the larger of the
operands' and the result's per-device size (all-gather is charged by its
gathered output, reduce-scatter by its input, all-reduce by its payload),
which matches ring-algorithm traffic to within the (n−1)/n factor.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI.
"""
from __future__ import annotations

import dataclasses
import json
import re

import numpy as np

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
_COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-device bytes moved by each collective kind in an HLO module."""
    # first pass: map value name → result bytes
    sizes: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        eq_type = rhs.split(" ", 1)[0]
        sizes[name] = _shape_bytes(eq_type)

    out = {k: 0 for k in _COLLECTIVE_KINDS}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        kind = None
        for k in _COLLECTIVE_KINDS:
            # op name appears right after the result type
            if re.search(rf"\]\S*\s+{k}(-start|-done)?\(", rhs):
                kind = k
                break
        if kind is None:
            continue
        if "-done(" in rhs:
            continue  # the start op already carries the payload
        out_bytes = sizes.get(name, 0)
        operand_bytes = sum(
            sizes.get(op, 0)
            for op in re.findall(r"%[\w.\-]+", rhs.split("(", 1)[1])
        )
        out[kind] += max(out_bytes, operand_bytes)
    return out


@dataclasses.dataclass
class CellReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    kind: str                    # train | prefill | decode | spatial
    flops_per_device: float
    bytes_per_device: float
    collective_per_device: dict
    temp_bytes: int
    arg_bytes: int
    out_bytes: int
    model_flops: float           # 6·N·D (train) / 2·N·D (inference)
    notes: str = ""

    # --- derived roofline terms (seconds) --------------------------------
    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return sum(self.collective_per_device.values()) / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant-term-bound step time that is useful
        model compute: (model_flops / (chips·peak)) / max(term)."""
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        bound = max(self.compute_s, self.memory_s, self.collective_s)
        return ideal / bound if bound else 0.0

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(compute_s=self.compute_s, memory_s=self.memory_s,
                 collective_s=self.collective_s, dominant=self.dominant,
                 useful_flops_ratio=self.useful_flops_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d


def analyze_compiled(compiled, *, chips: int) -> dict:
    """Extract flops/bytes/collectives/memory from a compiled executable."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older API returned [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    mem = compiled.memory_analysis()
    try:
        text = compiled.as_text()
        coll = collective_bytes(text)
    except Exception as e:  # pragma: no cover
        coll = {"error": str(e)}
    return {
        "flops_per_device": flops,
        "bytes_per_device": byts,
        "collective_per_device": coll,
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "arg_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "out_bytes": getattr(mem, "output_size_in_bytes", 0),
    }


def save_report(path: str, report: CellReport) -> None:
    with open(path, "w") as f:
        json.dump(report.to_json(), f, indent=2)
