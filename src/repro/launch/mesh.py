"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state (the dry-run sets the fake device count before any
jax initialisation)."""
from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16×16 = 256 chips per pod ('data', 'model'); the multi-pod variant
    adds a leading 2-pod axis → 512 chips ('pod', 'data', 'model')."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> jax.sharding.Mesh:
    return compat.make_mesh(shape, axes)


def single_device_mesh() -> jax.sharding.Mesh:
    return make_mesh((1, 1), ("data", "model"))
