"""Spatial query launcher — the paper's workload end-to-end.

    python -m repro.launch.spatial --dataset lakes --scale 0.02 \\
        --query-frac 0.05 --engine broadcast

Builds the STR tree on the host, places it on the active mesh, runs the
batched query pipeline, and cross-checks a sample against the oracle.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from repro.configs import rtree_paper
from repro.core import cpu_baseline, engine, rtree, subtree
from repro.data import datasets
from repro.kernels import ref
from repro.launch import mesh as meshmod


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="lakes",
                    choices=list(datasets.DATASETS))
    ap.add_argument("--scale", type=float, default=0.02,
                    help="fraction of the paper's dataset size")
    ap.add_argument("--query-frac", type=float, default=0.05)
    ap.add_argument("--engine", default="broadcast",
                    choices=["broadcast", "subtree", "cpu"])
    ap.add_argument("--batch", type=int, default=10_000)
    args = ap.parse_args()

    sc = {c.dataset: c for c in rtree_paper.SPATIAL_CONFIGS.values()}[
        args.dataset]
    n = max(1000, int(sc.num_rects * args.scale))
    print(f"dataset {args.dataset}: {n} rects (paper: {sc.num_rects})")
    rects = datasets.load(args.dataset, n=n)
    queries = datasets.make_queries(rects, args.query_frac)
    print(f"queries: {len(queries)} ({args.query_frac:.0%})")

    mesh = meshmod.single_device_mesh() if jax.device_count() == 1 \
        else meshmod.make_production_mesh()
    b, f = rtree.choose_parameters(n, mesh.size)
    t0 = time.perf_counter()
    tree = rtree.build_str_3level(rects, b, f)
    print(f"host STR build (B={b}, F={f}): {time.perf_counter()-t0:.2f}s, "
          f"{tree.num_leaves} leaves, {tree.num_l1} level-1 nodes")

    t0 = time.perf_counter()
    if args.engine == "broadcast":
        eng = engine.BroadcastEngine(tree, mesh, batch_size=args.batch)
        counts = eng.query(queries)
        stats = eng.transfer_stats(len(queries))
    elif args.engine == "subtree":
        eng = subtree.SubtreeEngine(rects, mesh, leaf_capacity=max(b, 32),
                                    batch_size=args.batch)
        counts = eng.query(queries)
        stats = eng.transfer_stats(len(queries))
    else:
        counts = cpu_baseline.parallel_query(tree, queries)
        stats = {}
    dt = time.perf_counter() - t0
    print(f"{args.engine} engine: {dt:.2f}s "
          f"({len(queries)/dt:.0f} queries/s), "
          f"total overlaps {int(counts.sum())}")
    if stats:
        print("transfer model:", stats)

    sample = queries[:200]
    want = ref.overlap_counts_np(sample, rects)
    assert (counts[:200] == want).all(), "engine/oracle mismatch"
    print("oracle cross-check: OK (200 queries)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
