"""Training launcher.

    python -m repro.launch.train --arch llama3.2-1b --smoke \\
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Uses the assigned full config by default (real-cluster entry point); --smoke
selects the reduced config that fits this CPU container.  --resume continues
from the latest checkpoint in --ckpt-dir (fault-tolerant restart path).
"""
from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.launch import mesh as meshmod
from repro.train import train_loop


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU container scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--mesh", default="auto",
                    choices=["auto", "single", "multi"],
                    help="auto = 1-device (container); single/multi = "
                         "production meshes (requires the devices)")
    args = ap.parse_args()

    if args.mesh == "auto":
        mesh = meshmod.single_device_mesh() if jax.device_count() == 1 \
            else meshmod.make_production_mesh()
    else:
        mesh = meshmod.make_production_mesh(multi_pod=args.mesh == "multi")

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    res = train_loop.train(
        cfg, mesh, steps=args.steps, batch_size=args.batch,
        seq_len=args.seq, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, resume=not args.no_resume,
        lr=args.lr, grad_accum=args.grad_accum)
    print(f"final loss: {res['losses'][-1]:.4f} "
          f"(start {res['losses'][0]:.4f}, {len(res['losses'])} steps)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
