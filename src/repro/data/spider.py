"""SPIDER-style synthetic spatial data generation.

Reimplements the rectangle distributions of the SPIDER spatial data generator
(Katiyar et al., https://spider.cs.ucr.edu/ — used by the paper for its 16M
rectangle / 3.99M query synthetic workload).  The container is offline, so we
generate from the published distribution definitions: uniform, gaussian,
diagonal, bit, sierpinski and parcel.  All outputs use the paper's
fixed-precision int32 coordinate scheme: float coordinates in [0, 1] scaled
by ``SCALE`` and rounded.

Every generator is deterministic in its seed.
"""
from __future__ import annotations

import numpy as np

SCALE = 1_000_000  # fixed-precision scaling: 1e6 ticks over the unit square


def _to_int_rects(cx, cy, w, h) -> np.ndarray:
    """Clip centre/size float arrays to the unit square and convert to int32
    corner rects [xmin, ymin, xmax, ymax]."""
    x0 = np.clip(cx - w / 2, 0.0, 1.0)
    y0 = np.clip(cy - h / 2, 0.0, 1.0)
    x1 = np.clip(cx + w / 2, 0.0, 1.0)
    y1 = np.clip(cy + h / 2, 0.0, 1.0)
    r = np.stack([x0, y0, x1, y1], axis=1)
    r = np.round(r * SCALE).astype(np.int32)
    # enforce min <= max after rounding
    r[:, 2] = np.maximum(r[:, 2], r[:, 0])
    r[:, 3] = np.maximum(r[:, 3], r[:, 1])
    return r


def _sizes(rng: np.random.Generator, n: int, max_size: float) -> tuple:
    w = rng.uniform(0.0, max_size, n)
    h = rng.uniform(0.0, max_size, n)
    return w, h


def uniform(n: int, seed: int = 0, max_size: float = 0.001) -> np.ndarray:
    rng = np.random.default_rng(seed)
    cx, cy = rng.uniform(0, 1, n), rng.uniform(0, 1, n)
    return _to_int_rects(cx, cy, *_sizes(rng, n, max_size))


def gaussian(n: int, seed: int = 0, max_size: float = 0.001) -> np.ndarray:
    rng = np.random.default_rng(seed)
    cx = np.clip(rng.normal(0.5, 0.1, n), 0, 1)
    cy = np.clip(rng.normal(0.5, 0.1, n), 0, 1)
    return _to_int_rects(cx, cy, *_sizes(rng, n, max_size))


def diagonal(
    n: int, seed: int = 0, percentage: float = 0.5, buffer: float = 0.5,
    max_size: float = 0.001,
) -> np.ndarray:
    """SPIDER diagonal: `percentage` of points exactly on the diagonal, the
    rest displaced by a normal with sd = buffer/5."""
    rng = np.random.default_rng(seed)
    on_diag = rng.uniform(0, 1, n) < percentage
    base = rng.uniform(0, 1, n)
    disp = rng.normal(0, buffer / 5, n) / np.sqrt(2.0)
    cx = np.where(on_diag, base, np.clip(base + disp, 0, 1))
    cy = np.where(on_diag, base, np.clip(base - disp, 0, 1))
    return _to_int_rects(cx, cy, *_sizes(rng, n, max_size))


def bit(
    n: int, seed: int = 0, probability: float = 0.2, digits: int = 10,
    max_size: float = 0.001,
) -> np.ndarray:
    """SPIDER bit distribution: each of `digits` binary fraction bits set with
    `probability` — produces clustered, axis-aligned banding."""
    rng = np.random.default_rng(seed)

    def coord():
        bits = rng.uniform(0, 1, (n, digits)) < probability
        weights = 0.5 ** np.arange(1, digits + 1)
        return bits @ weights

    return _to_int_rects(coord(), coord(), *_sizes(rng, n, max_size))


def sierpinski(n: int, seed: int = 0, max_size: float = 0.001) -> np.ndarray:
    """Chaos-game Sierpinski triangle (SPIDER's fractal distribution)."""
    rng = np.random.default_rng(seed)
    verts = np.array([[0.0, 0.0], [1.0, 0.0], [0.5, np.sqrt(3) / 2]])
    choices = rng.integers(0, 3, size=n + 32)
    pts = np.empty((n + 32, 2))
    p = np.array([0.1, 0.1])
    for i, c in enumerate(choices):
        p = (p + verts[c]) / 2.0
        pts[i] = p
    pts = pts[32:]  # burn-in
    return _to_int_rects(pts[:, 0], pts[:, 1], *_sizes(rng, n, max_size))


def parcel(
    n: int, seed: int = 0, split_range: float = 0.5, dither: float = 0.1
) -> np.ndarray:
    """SPIDER parcel: recursive binary space partition into n boxes, each
    dithered — models cadastral/land-parcel data (non-overlapping tiling)."""
    rng = np.random.default_rng(seed)
    boxes = [(0.0, 0.0, 1.0, 1.0)]
    while len(boxes) < n:
        x0, y0, x1, y1 = boxes.pop(0)
        w, h = x1 - x0, y1 - y0
        frac = rng.uniform(split_range, 1.0 - split_range) if split_range < 0.5 else 0.5
        frac = np.clip(frac, 0.1, 0.9)
        if w >= h:
            xm = x0 + frac * w
            boxes += [(x0, y0, xm, y1), (xm, y0, x1, y1)]
        else:
            ym = y0 + frac * h
            boxes += [(x0, y0, x1, ym), (x0, ym, x1, y1)]
    boxes = np.array(boxes[:n])
    w = boxes[:, 2] - boxes[:, 0]
    h = boxes[:, 3] - boxes[:, 1]
    d = rng.uniform(0, dither, (n, 2))
    boxes[:, 2] -= w * d[:, 0]
    boxes[:, 3] -= h * d[:, 1]
    cx = (boxes[:, 0] + boxes[:, 2]) / 2
    cy = (boxes[:, 1] + boxes[:, 3]) / 2
    return _to_int_rects(cx, cy, boxes[:, 2] - boxes[:, 0], boxes[:, 3] - boxes[:, 1])


DISTRIBUTIONS = {
    "uniform": uniform,
    "gaussian": gaussian,
    "diagonal": diagonal,
    "bit": bit,
    "sierpinski": sierpinski,
    "parcel": parcel,
}


def generate(distribution: str, n: int, seed: int = 0, **kw) -> np.ndarray:
    if distribution not in DISTRIBUTIONS:
        raise KeyError(f"unknown distribution {distribution!r}")
    return DISTRIBUTIONS[distribution](n, seed=seed, **kw)
