"""Dataset and query-workload construction matching the paper's Table I.

The paper evaluates on UCR-STAR's Sports (999K MBRs) and Lakes (8.4M MBRs)
plus a SPIDER synthetic (16M MBRs).  UCR-STAR is not reachable from this
offline container, so :func:`sports` and :func:`lakes` build *surrogates*
with the same cardinality and qualitatively matched spatial statistics
(Sports: clustered point-like facilities → gaussian mixture; Lakes: skewed
global coverage with heavy clustering → diagonal+gaussian mixture).  The
synthetic dataset is generated exactly as the paper describes (SPIDER,
uniform).  Query workloads follow Table I: query counts at 1/5/10/25% of the
dataset cardinality, query rectangles sampled from the data distribution
(range queries over occupied space).
"""
from __future__ import annotations

import numpy as np

from repro.data import spider

QUERY_FRACTIONS = {"1%": 0.01, "5%": 0.05, "10%": 0.10, "25%": 0.25}


def sports(n: int = 999_000, seed: int = 7) -> np.ndarray:
    """Sports surrogate: 999K small rects in dense metro clusters."""
    rng = np.random.default_rng(seed)
    n_clusters = 200
    centers = rng.uniform(0, 1, (n_clusters, 2))
    weights = rng.dirichlet(np.full(n_clusters, 0.5))
    assign = rng.choice(n_clusters, size=n, p=weights)
    spread = rng.uniform(0.002, 0.02, n_clusters)
    cx = np.clip(centers[assign, 0] + rng.normal(0, 1, n) * spread[assign], 0, 1)
    cy = np.clip(centers[assign, 1] + rng.normal(0, 1, n) * spread[assign], 0, 1)
    w = rng.uniform(0, 2e-4, n)
    h = rng.uniform(0, 2e-4, n)
    return spider._to_int_rects(cx, cy, w, h)


def lakes(n: int = 8_400_000, seed: int = 11) -> np.ndarray:
    """Lakes surrogate: 8.4M rects, broad coverage + strong regional skew."""
    third = n // 3
    a = spider.diagonal(third, seed=seed, percentage=0.3, buffer=0.8,
                        max_size=5e-4)
    b = spider.gaussian(third, seed=seed + 1, max_size=5e-4)
    c = spider.uniform(n - 2 * third, seed=seed + 2, max_size=5e-4)
    rects = np.concatenate([a, b, c], axis=0)
    rng = np.random.default_rng(seed + 3)
    return rects[rng.permutation(n)]


def synthetic(n: int = 16_000_000, seed: int = 13) -> np.ndarray:
    """The paper's SPIDER synthetic: 16M uniform rectangles."""
    return spider.uniform(n, seed=seed, max_size=2e-4)


def make_queries(
    rects: np.ndarray, fraction: float, seed: int = 101,
    expand: float = 1e-3,
) -> np.ndarray:
    """Range-query workload: sample `fraction`·N data rects and expand them
    slightly — queries track the data distribution, as in range-query
    benchmarks over UCR-STAR extracts."""
    rng = np.random.default_rng(seed)
    n = rects.shape[0]
    q = max(1, int(round(n * fraction)))
    idx = rng.choice(n, size=q, replace=q > n)
    # 64-bit intermediate: expansion arithmetic may overflow int32 corners
    base = rects[idx].astype(np.int64)    # pallint: disable=PL109
    grow = int(expand * spider.SCALE)
    g = rng.integers(0, max(grow, 1), size=(q, 2))
    out = np.stack(
        [base[:, 0] - g[:, 0], base[:, 1] - g[:, 1],
         base[:, 2] + g[:, 0], base[:, 3] + g[:, 1]],
        axis=1,
    )
    return np.clip(out, 0, spider.SCALE).astype(np.int32)


DATASETS = {"sports": sports, "lakes": lakes, "synthetic": synthetic}


def load(name: str, n: int | None = None, seed: int | None = None) -> np.ndarray:
    fn = DATASETS[name]
    kw = {}
    if n is not None:
        kw["n"] = n
    if seed is not None:
        kw["seed"] = seed
    return fn(**kw)
