"""Synthetic LM token pipeline.

Deterministic, seekable token stream with a Zipfian unigram distribution and
document structure (BOS/EOS packing) — enough realism for throughput work
without external data.  ``Seekable`` matters for fault tolerance: the stream
is indexed by global step, so a restarted job regenerates exactly the batches
it would have seen (checkpoint stores only the step counter).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

BOS = 1
EOS = 2


def _zipf_probs(vocab: int, alpha: float = 1.1) -> np.ndarray:
    ranks = np.arange(3, vocab, dtype=np.float64)
    p = 1.0 / ranks**alpha
    return p / p.sum()


class TokenStream:
    """Deterministic packed-document token stream."""

    def __init__(self, vocab: int, seed: int = 0, mean_doc_len: int = 512):
        self.vocab = vocab
        self.seed = seed
        self.mean_doc_len = mean_doc_len
        self._probs = _zipf_probs(vocab)

    def batch(self, step: int, batch_size: int, seq_len: int) -> np.ndarray:
        """(batch_size, seq_len) int32 for a given global step — pure
        function of (seed, step)."""
        rng = np.random.default_rng((self.seed, step))
        toks = rng.choice(
            self.vocab - 3, size=(batch_size, seq_len), p=self._probs
        ).astype(np.int32) + 3
        # document boundaries: geometric inter-arrival EOS/BOS pairs
        boundary = rng.random((batch_size, seq_len)) < 1.0 / self.mean_doc_len
        toks = np.where(boundary, EOS, toks)
        toks[:, 0] = BOS
        return toks


def device_batch(mesh: Mesh, tokens: np.ndarray) -> jax.Array:
    """Place a host batch onto the mesh with batch-dim sharding over the
    data-parallel axes (drops non-dividing axes)."""
    from repro.parallel.sharding import logical_to_spec
    spec = logical_to_spec(("batch", None), mesh, tokens.shape)
    return jax.device_put(tokens, NamedSharding(mesh, spec))
