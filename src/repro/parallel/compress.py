"""Gradient compression for slow (cross-pod / DCN) links.

int8 block-quantised all-reduce with error feedback: each leaf is scaled by
its per-leaf absmax, rounded to int8, psum'd in int32, and de-quantised; the
quantisation residual is carried in an error-feedback accumulator so the
compression bias vanishes over steps (standard EF-SGD result).

Intended use: the cross-pod gradient reduction in
``train_loop.make_train_step(cross_pod="compressed")`` — intra-pod reductions
stay full-precision over fast ICI; only the 'pod' axis (DCN in a real
multi-pod deployment) sees compressed traffic, cutting cross-pod gradient
bytes 4× (fp32→int8).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro import compat


def _quantize(x: jnp.ndarray):
    absmax = jnp.max(jnp.abs(x)) + 1e-12
    scale = absmax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_psum_mean(tree: Any, axis_name: str, err: Any | None = None):
    """Mean over `axis_name` with int8 quantisation + error feedback.

    Returns (reduced_tree, new_err).  `err` is a tree like `tree` (fp32) or
    None on the first step.
    """
    n = compat.axis_size(axis_name)

    def one(g, e):
        g32 = g.astype(jnp.float32)
        if e is not None:
            g32 = g32 + e
        q, scale = _quantize(g32)
        # int32 accumulate avoids int8 overflow; scales averaged alongside.
        s = jax.lax.psum(q.astype(jnp.int32), axis_name)
        scale_sum = jax.lax.psum(scale, axis_name)
        # each participant quantised with its own scale; use the mean scale
        # (leaf-wise scales are near-identical for gradient shards).
        out = s.astype(jnp.float32) * (scale_sum / n) / n
        new_e = g32 - q.astype(jnp.float32) * scale
        return out.astype(g.dtype), new_e

    if err is None:
        err = jax.tree_util.tree_map(lambda _: None, tree,
                                     is_leaf=lambda x: x is None)
        flat, treedef = jax.tree_util.tree_flatten(tree)
        outs = [one(g, None) for g in flat]
    else:
        flat, treedef = jax.tree_util.tree_flatten(tree)
        eflat = jax.tree_util.tree_leaves(err)
        outs = [one(g, e) for g, e in zip(flat, eflat)]
    red = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_err = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return red, new_err


def zero_error_state(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
