"""GPipe-style pipeline parallelism over the 'pod' axis.

On a multi-pod deployment the inter-pod links (DCN) are an order of magnitude
slower than intra-pod ICI, so the classic alternative to cross-pod DP is to
make pods pipeline *stages*: each pod owns a contiguous block of layers and
only (microbatch × d_model) activations cross the pod boundary per tick —
instead of a full gradient reduction.

Implementation: a partial-manual ``shard_map`` over 'pod' ('data'/'model'
stay auto, so FSDP/TP inside a stage keep working); the schedule is a
``lax.scan`` over ``M + S − 1`` ticks.  At every tick a stage processes one
microbatch (bubble ticks compute-but-discard, the standard GPipe cost: the
bubble fraction is (S−1)/(M+S−1)), then hands activations to the next stage
with ``collective_permute``.  The whole schedule is differentiable — autodiff
transposes ``ppermute`` into the reverse-direction sends, generating the
backward pipeline automatically.

Restriction: dense-family configs with ``n_layers % num_stages == 0`` (the
dry-run demonstrates it on llama3.2-1b across 2 pods).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.models import api, layers as L, transformer as T
from repro.models.base import ModelConfig
from repro.parallel.sharding import exclude_axes, shard


def _split_stages(params: dict, num_stages: int) -> dict:
    """(L, ...) stacked layers → (S, L/S, ...)."""
    def reshape(x):
        l = x.shape[0]
        return x.reshape(num_stages, l // num_stages, *x.shape[1:])
    out = dict(params)
    out["layers"] = jax.tree_util.tree_map(reshape, params["layers"])
    return out


def make_pp_loss_fn(cfg: ModelConfig, mesh: Mesh, num_microbatches: int):
    """Returns loss_fn(params, batch) running the GPipe schedule over 'pod'.

    params: the standard stacked-layer tree (reshaped internally); batch:
    {'tokens': (B, S)} with B % num_microbatches == 0.
    """
    assert cfg.family in ("dense",), "PP demo covers the dense family"
    s_stages = mesh.shape["pod"]
    assert cfg.n_layers % s_stages == 0
    m = num_microbatches

    def loss_fn(params, batch):
        params_s = _split_stages(params, s_stages)

        def per_stage(stage_layers, embed, final_norm, lm_head, tokens):
            # stage_layers: (1, L/S, ...) — this stage's block
            stage_layers = jax.tree_util.tree_map(
                lambda x: x[0], stage_layers)
            stage = jax.lax.axis_index("pod")
            b, s_len = tokens.shape
            mb = b // m
            mbs = tokens.reshape(m, mb, s_len)
            cos, sin = L.rope_cos_sin(
                jnp.broadcast_to(jnp.arange(s_len)[None], (mb, s_len)),
                cfg.head_dim, cfg.rope_theta)

            def run_block(x):
                def body(x, lp):
                    x, _ = T.attn_block(cfg, lp, x, cos, sin,
                                        window=cfg.window)
                    x = T.mlp_block(cfg, lp, x)
                    return x, None
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies.nothing_saveable)
                x, _ = jax.lax.scan(body, x, stage_layers)
                return x

            def tick(carry, t):
                recv, loss_acc = carry
                t_mb = t - stage
                active = (t_mb >= 0) & (t_mb < m)
                mb_idx = jnp.clip(t_mb, 0, m - 1)
                toks = jax.lax.dynamic_index_in_dim(
                    mbs, mb_idx, axis=0, keepdims=False)
                x_first = L.embed_lookup(embed.astype(L.COMPUTE_DTYPE), toks)
                x_in = jnp.where(stage == 0, x_first, recv)
                y = run_block(x_in)
                # last stage: loss for this microbatch
                h = L.rms_norm(y, final_norm, cfg.norm_eps)
                logits = (h @ lm_head.astype(h.dtype)).astype(jnp.float32)
                lg = logits[:, :-1]
                tgt = toks[:, 1:]
                logz = jax.nn.logsumexp(lg, axis=-1)
                gold = jnp.take_along_axis(
                    lg, tgt[..., None], axis=-1)[..., 0]
                mb_loss = (logz - gold).mean()
                is_last = stage == s_stages - 1
                loss_acc = loss_acc + jnp.where(active & is_last, mb_loss, 0.0)
                # hand activations downstream
                perm = [(i, i + 1) for i in range(s_stages - 1)]
                recv_new = jax.lax.ppermute(y, "pod", perm)
                return (recv_new, loss_acc), None

            b0 = jnp.zeros((mb, s_len, cfg.d_model), L.COMPUTE_DTYPE)
            (recv, loss_acc), _ = jax.lax.scan(
                tick, (b0, jnp.zeros((), jnp.float32)),
                jnp.arange(m + s_stages - 1))
            # only the last stage accumulated loss; share it everywhere
            return jax.lax.psum(loss_acc, "pod") / m

        with exclude_axes({"pod"}):
            lm_head = params_s.get(
                "lm_head",
                params_s["embed"].T if "lm_head" not in params_s else None)
            if "lm_head" not in params_s:
                lm_head = params_s["embed"].T
            loss = compat.shard_map(
                per_stage, mesh=mesh,
                in_specs=(P("pod"), P(), P(), P(), P()),
                out_specs=P(),
                axis_names={"pod"}, check_vma=False,
            )(params_s["layers"], params_s["embed"],
              params_s["final_norm"], lm_head, batch["tokens"])
        return loss

    return loss_fn
