"""Logical-axis sharding rules and the mesh context.

The framework follows the paper's communication doctrine (DESIGN.md Sec 4):
replicate what is small and shared, shard what is bulky along a contiguous
axis, reduce fixed-size partials.  Concretely:

logical axes → mesh axes
    batch   → ('pod', 'data')   data parallelism (cross-pod DP by default)
    fsdp    → 'data'            parameter/optimizer-state sharding (ZeRO-3)
    tp      → 'model'           tensor parallelism (heads·head_dim / ffn dims)
    seq     → 'model'           sequence sharding (KV caches for decode)
    expert  → 'model'           expert parallelism for MoE layers
    stage   → 'pod'             pipeline stages (optional PP mode)

Rules degrade gracefully: axes missing from the active mesh are dropped, and
an axis whose size does not divide the tensor dimension is dropped too (GSPMD
could pad, but padding a batch of 1 across 32 devices is pure waste — the
long_500k cells hit exactly this).
"""
from __future__ import annotations

import re
import threading
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

LOGICAL_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "tokens": ("pod", "data", "model"),  # flattened B·S token streams
    "fsdp": ("data",),
    "tp": ("model",),
    "seq": ("model",),
    "expert": ("model",),
    "stage": ("pod",),
}

_ctx = threading.local()


def set_current_mesh(mesh: Mesh | None) -> None:
    _ctx.mesh = mesh


def current_mesh() -> Mesh | None:
    return getattr(_ctx, "mesh", None)


class use_mesh:
    def __init__(self, mesh: Mesh | None):
        self.mesh = mesh

    def __enter__(self):
        self.prev = current_mesh()
        set_current_mesh(self.mesh)
        return self.mesh

    def __exit__(self, *exc):
        set_current_mesh(self.prev)


def set_excluded_axes(axes: frozenset[str]) -> None:
    """Mesh axes that logical rules must not use — e.g. 'pod' while it serves
    as the manual pipeline-stage axis inside a shard_map."""
    _ctx.excluded = axes


def excluded_axes() -> frozenset[str]:
    return getattr(_ctx, "excluded", frozenset())


class exclude_axes:
    def __init__(self, axes):
        self.axes = frozenset(axes)

    def __enter__(self):
        self.prev = excluded_axes()
        set_excluded_axes(self.prev | self.axes)

    def __exit__(self, *exc):
        set_excluded_axes(self.prev)


def gather_safe_mode() -> bool:
    """True inside partial-manual shard_map regions (pipeline / compressed
    cross-pod), where XLA's SPMD partitioner CHECK-fails on vocab-sharded
    gathers (xla spmd_partitioner_util.cc:504, subgroup-manual +
    PartitionGather).  Embedding lookups switch to a one-hot matmul there —
    the contraction partitions cleanly."""
    return bool(excluded_axes())


def resolve_axes(logical: str | None, mesh: Mesh) -> tuple[str, ...]:
    """Logical name → the subset of its mesh axes present in `mesh`."""
    if logical is None:
        return ()
    excl = excluded_axes()
    return tuple(a for a in LOGICAL_RULES[logical]
                 if a in mesh.axis_names and a not in excl)


def logical_to_spec(
    spec: tuple[str | None, ...], mesh: Mesh, shape: tuple[int, ...] | None = None
) -> P:
    """Resolve a logical spec to a PartitionSpec on `mesh`.

    If `shape` is given, mesh axes whose product does not divide the
    corresponding dimension are dropped (no silent GSPMD padding).
    """
    out = []
    for i, name in enumerate(spec):
        axes = resolve_axes(name, mesh)
        if shape is not None and axes:
            size = int(np.prod([mesh.shape[a] for a in axes]))
            while axes and shape[i] % size != 0:
                axes = axes[:-1]
                size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
        out.append(axes if len(axes) > 1 else (axes[0] if axes else None))
    return P(*out)


def shard(x: jax.Array, *logical: str | None) -> jax.Array:
    """with_sharding_constraint using logical axis names; no-op without a mesh
    (single-device smoke tests) or on a 1-device mesh."""
    mesh = current_mesh()
    if mesh is None or mesh.size == 1:
        return x
    spec = logical_to_spec(tuple(logical), mesh, tuple(x.shape))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter sharding rules (path-regex → logical spec).
# ---------------------------------------------------------------------------
# Matched against the '/'-joined pytree path of each parameter leaf.  The
# first matching rule wins; specs apply to the *trailing* dims of the leaf
# (stacked-layer leading dims are replicated).

PARAM_RULES: list[tuple[str, tuple[str | None, ...]]] = [
    (r"embed",                    ("tp", "fsdp")),       # (vocab, d)
    (r"lm_head",                  ("fsdp", "tp")),       # (d, vocab)
    (r"(wq|wk|wv|qkv)(_b)?$",     ("fsdp", "tp")),       # (d, heads*hd)
    (r"wo$",                      ("tp", "fsdp")),       # (heads*hd, d)
    (r"(w_gate|w_up)$",           ("fsdp", "tp")),       # (d, ff)
    (r"w_down$",                  ("tp", "fsdp")),       # (ff, d)
    (r"router$",                  ("fsdp", None)),       # (d, E)
    (r"experts/(w_gate|w_up)",    ("expert", "fsdp", None)),  # (E, d, f)
    (r"experts/w_down",           ("expert", None, "fsdp")),  # (E, f, d)
    (r"shared/(w_gate|w_up)$",    ("fsdp", "tp")),
    (r"shared/w_down$",           ("tp", "fsdp")),
    (r"in_proj$",                 ("fsdp", "tp")),       # mamba (d, 2*d_inner)
    (r"conv_w$",                  ("tp", None)),         # (d_inner, conv)
    (r"conv_b$",                  ("tp",)),
    (r"x_proj$",                  ("tp", None)),         # (d_inner, dt_rank+2n)
    (r"dt_proj(_b)?$",            (None, "tp")),         # (dt_rank, d_inner)
    (r"A_log$",                   ("tp", None)),         # (d_inner, n)
    (r"D$",                       ("tp",)),
    (r"out_proj$",                ("tp", "fsdp")),       # (d_inner, d)
    (r"(rg_x|rg_gate)$",          ("fsdp", "tp")),       # griffin (d, width)
    (r"(rg_out)$",                ("tp", "fsdp")),       # (width, d)
    (r"(lambda_p|rg_a_w|rg_i_w)$", ("tp",) * 1),         # (width,) gates
    (r"rg_a_b$|rg_i_b$",          ("tp",)),
    (r"pos_embed",                (None, "fsdp")),       # (S, d)
    (r"(bias|_b)$",               ("tp",)),              # 1-D biases on tp dim
    (r"norm|scale",               (None,)),              # replicated norms
]


def spec_for_path(path: str, ndim: int) -> tuple[str | None, ...]:
    for pat, spec in PARAM_RULES:
        if re.search(pat, path):
            if len(spec) > ndim:
                spec = spec[-ndim:] if ndim > 0 else ()
            return (None,) * (ndim - len(spec)) + tuple(spec)
    return (None,) * ndim


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(params: Any, mesh: Mesh) -> Any:
    """Pytree of PartitionSpec matching `params` (works on ShapeDtypeStructs)."""

    def leaf_spec(path, leaf):
        logical = spec_for_path(_path_str(path), leaf.ndim)
        return logical_to_spec(logical, mesh, tuple(leaf.shape))

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def param_shardings(params: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_specs(params, mesh)
    )
