"""§Roofline report generator: reads the dry-run JSON cells and renders the
markdown table for EXPERIMENTS.md (terms in seconds, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs ratio, one-line lever per cell)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks import table5_energy

LEVERS = {
    "compute": "raise MXU utilisation: fuse small ops, larger microbatch, "
               "bf16 everywhere",
    "memory": "cut bytes: tighter remat policy, fp8/bf16 staging, fuse "
              "elementwise chains, larger arithmetic intensity tiles",
    "collective": "cut collective bytes: bf16 collectives, reduce-scatter "
                  "instead of all-reduce+slice, overlap with compute, "
                  "resharding-free layouts",
}


def load_cells(dir_: str) -> list[dict]:
    cells = []
    for fn in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(fn) as f:
            cells.append(json.load(f))
    return cells


def render(dir_: str = "results/dryrun", mesh_filter: str | None = "16x16",
           out: str | None = None) -> str:
    cells = load_cells(dir_)
    if mesh_filter:
        cells = [c for c in cells if c["mesh"] == mesh_filter]
    lines = [
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
        "| dominant | MODEL_FLOPs | HLO_FLOPs | useful | roofline frac "
        "| TPU energy (J) | lever |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in sorted(cells, key=lambda c: (c["arch"], c["shape"])):
        hlo_total = c["flops_per_device"] * c["chips"]
        energy = table5_energy.tpu_energy_j(
            hlo_total, c["bytes_per_device"] * c["chips"])
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {c['compute_s']:.3e} | {c['memory_s']:.3e} "
            f"| {c['collective_s']:.3e} | **{c['dominant']}** "
            f"| {c['model_flops']:.2e} | {hlo_total:.2e} "
            f"| {c['useful_flops_ratio']:.2f} "
            f"| {c['roofline_fraction']:.3f} "
            f"| {energy:.1f} | {LEVERS[c['dominant']][:46]}… |")
    text = "\n".join(lines)
    if out:
        with open(out, "w") as f:
            f.write(text)
    return text


if __name__ == "__main__":
    import sys
    print(render(*(sys.argv[1:] or [])))
