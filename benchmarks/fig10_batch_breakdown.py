"""Paper Fig 10: average per-batch timing breakdown — query transfer, kernel
execution, result retrieval.

The paper's point: in the broadcast design the kernel dominates each batch
and communication is a thin slice.  Per-batch transfer volumes are exact
(batch × 16 B queries in, batch × 4 B counts out); the measured slices come
from the shared blocking harness
(:func:`repro.obs.phases.measure_query_phases` — the same helper
``benchmarks/regress.py`` records into ``BENCH_pipeline.json``, so the two
reports agree by construction); transfer times are additionally modeled at
UPMEM host-bandwidth and at TPU ICI bandwidth.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import rtree
from repro.core import engine
from repro.data import datasets
from repro.obs import phases as obs_phases

HOST_BW = 8e9
ICI_BW = 50e9


def run(full: bool = False) -> list[dict]:
    name = "lakes"
    n = None if full else common.SCALED[name]
    rects = datasets.load(name, n=n)
    queries = datasets.make_queries(rects, 0.05, seed=47)
    mesh = common.mesh1()
    b, f = rtree.choose_parameters(len(rects), 8)
    tree = rtree.build_str_3level(rects, b, f)
    eng = engine.BroadcastEngine(tree, mesh, batch_size=10_000)

    batch = np.asarray(queries[:10_000], np.int32)
    if batch.shape[0] < 10_000:
        batch = np.concatenate([batch, np.tile(
            [2**31 - 1, 2**31 - 1, -2**31, -2**31],
            (10_000 - batch.shape[0], 1)).astype(np.int32)])
    step, operands, rep_sh = common.bench_step(eng)
    slices = obs_phases.measure_query_phases(step, operands, batch, rep_sh)
    t_kernel = slices["kernel_s"]
    q_bytes = batch.nbytes
    r_bytes = batch.shape[0] * 4
    t_q_upmem, t_r_upmem = q_bytes / HOST_BW, r_bytes / HOST_BW
    t_q_tpu, t_r_tpu = q_bytes / ICI_BW, r_bytes / ICI_BW

    common.emit("fig10/lakes/query_transfer", t_q_upmem,
                f"bytes={q_bytes} tpu_s={t_q_tpu:.2e} "
                f"measured_s={slices['h2d_s']:.2e}")
    common.emit("fig10/lakes/kernel", t_kernel,
                f"fraction={t_kernel/(t_kernel+t_q_upmem+t_r_upmem):.3f}")
    common.emit("fig10/lakes/result_retrieval", t_r_upmem,
                f"bytes={r_bytes} tpu_s={t_r_tpu:.2e} "
                f"measured_s={slices['d2h_s']:.2e}")
    return [dict(query_transfer_s=t_q_upmem, kernel_s=t_kernel,
                 result_s=t_r_upmem,
                 h2d_measured_s=slices["h2d_s"],
                 d2h_measured_s=slices["d2h_s"])]


if __name__ == "__main__":
    run()
