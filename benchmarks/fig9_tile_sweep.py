"""Paper Fig 9 analogue: intra-device parallelism sweep.

On a DPU the knob is tasklet count (saturating at 8–11 from MRAM bandwidth
contention).  On TPU the corresponding knob is the Pallas tile shape
(TQ × TR): query-tile reuse raises arithmetic intensity linearly in TQ until
the VMEM working set or the count-matrix reduction dominates.  We report the
modeled arithmetic intensity per tile shape plus the measured kernel wall
time in interpret mode on a small workload (shape behaviour, not absolute
TPU performance) and the XLA-path chunking sweep as the measured stand-in.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks import common
from repro.data import spider
from repro.kernels import ops, ref

TILES = ((64, 256), (128, 512), (256, 1024), (512, 1024), (512, 2048),
         (1024, 2048))


def run(full: bool = False) -> list[dict]:
    del full
    rows = []
    rects = spider.uniform(100_000, seed=5)
    queries = spider.uniform(4096, seed=6, max_size=0.002)
    q = jnp.asarray(queries)
    r = jnp.asarray(rects)
    # measured XLA-path time (fixed math, chunk affects fusion/locality)
    for chunk in (256, 512, 1024, 2048, 4096):
        t = common.time_fn(
            lambda c=chunk: ref.overlap_counts_ref(q, r, query_chunk=c))
        common.emit(f"fig9/xla_chunk{chunk}", t, "")
    for tq, tr in TILES:
        # per-tile bytes: two coordinate tiles; ops: TQ×TR×8 int compares
        tile_bytes = (tq + tr) * 16
        tile_ops = tq * tr * 8
        intensity = tile_ops / tile_bytes
        vmem_kb = (tile_bytes + tq * tr // 8) / 1024  # + packed bool matrix
        rows.append(dict(tq=tq, tr=tr, intensity=intensity,
                         vmem_kb=vmem_kb))
        common.emit(f"fig9/tile_{tq}x{tr}", 0.0,
                    f"intensity_ops_per_byte={intensity:.1f} "
                    f"vmem_kb={vmem_kb:.0f}")
    return rows


if __name__ == "__main__":
    run()
