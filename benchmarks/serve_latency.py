"""Tail latency of the fault-tolerant serving loop under Poisson arrival.

Single-server section — two runs over one deterministic arrival schedule:

* ``clean`` — healthy steady state; the fast (device) path serves every
  request.
* ``chaos`` — the same load with a deterministic fault plan injected at the
  step/placement seams (straggler, transient device loss, corrupted counts).
  The point of the row is the *shape* of the tail: p99 absorbs the watchdog
  + retry budget while p50 stays near the clean run, and shed/expired/
  degraded rates quantify what availability cost the faults extracted.

Router section (``--replicas N``) — the multi-replica pool behind
:class:`repro.serve.router.SpatialRouter`, same arrival discipline:

* ``clean``            — healthy pool, hedging off.
* ``replica_crash``    — one replica crashes mid-run and stays down; the
  rows quantify what failover costs (reroutes, tail) at zero lost requests.
* ``straggler``        — one replica's device step is persistently slow,
  hedging off: the straggler owns the p99.
* ``straggler_hedged`` — identical fault plan with hedged retries on; the
  acceptance gate asserts the hedge measurably cuts that p99.

Writes ``BENCH_serve.json`` at the repo root and emits the usual CSV rows.

Usage: ``PYTHONPATH=src:. python -m benchmarks.serve_latency [--replicas N]``
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks import common
from repro.core import engine as beng
from repro.core import rtree
from repro.data import datasets, spider
from repro.obs import phases as obs_phases
from repro.obs import trace as obs_trace
from repro.serve.router import RouterConfig, SpatialRouter
from repro.serve.spatial_serve import ServeConfig, SpatialServer
from repro.testing import chaos

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")

NUM_RECTS = 20_000
NUM_REQUESTS = 2_000
ARRIVAL_RATE_QPS = 2_000.0       # Poisson arrival intensity
DEADLINE_S = 2.0

FAULT_PLAN = (
    chaos.Fault(chaos.STRAGGLER, at_call=3, count=1, delay_s=0.75),
    chaos.Fault(chaos.DEVICE_LOSS, at_call=8, count=2),
    chaos.Fault(chaos.CORRUPT, at_call=14, count=1),
)

# router section: per-request routing overhead caps useful throughput well
# below the micro-batched single-server number, so the pool sees a lighter
# open-loop schedule (same Poisson discipline, same seed across all rows)
ROUTER_REQUESTS = 600
ROUTER_RATE_QPS = 300.0
ROUTER_DEADLINE_S = 5.0
STRAGGLE_DELAY_S = 0.25


def _workload(seed: int = 5):
    rects = spider.uniform(NUM_RECTS, seed=seed)
    queries = datasets.make_queries(rects, 1.0, seed=seed + 1)
    reps = -(-NUM_REQUESTS // len(queries))
    queries = np.concatenate([queries] * reps)[:NUM_REQUESTS]
    tree = rtree.build_str_3level(
        rects, *rtree.choose_parameters(NUM_RECTS, 1))
    return rects, queries, tree


def _poisson_arrivals(n: int, rate_qps: float, seed: int = 7) -> np.ndarray:
    """Cumulative arrival offsets (seconds) for a Poisson process — fixed
    seed so the clean and chaos runs see the identical schedule."""
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_qps, size=n))


def _drive(srv: SpatialServer, queries: np.ndarray,
           arrivals: np.ndarray) -> list:
    """Open-loop load generator: submit each request at its scheduled
    arrival time regardless of how the server is keeping up."""
    srv.start()
    tickets = []
    t0 = time.perf_counter()
    try:
        for q, at in zip(queries, arrivals):
            lag = at - (time.perf_counter() - t0)
            if lag > 0:
                time.sleep(lag)
            tickets.append(srv.submit(q, deadline_s=DEADLINE_S))
    finally:
        srv.stop(drain=True, timeout=60.0)
    return tickets


def _summarize(label: str, srv: SpatialServer, tickets: list,
               want: np.ndarray) -> dict:
    m = srv.metrics()
    ok = [t for t in tickets if t.status == "ok"]
    # correctness gate: every completed response must be exact
    got = np.array([t.count for t in ok], dtype=np.int32)
    idx = [i for i, t in enumerate(tickets) if t.status == "ok"]
    np.testing.assert_array_equal(got, want[idx])
    lat = np.array([t.latency_s for t in ok], dtype=np.float64)
    row = dict(
        label=label,
        requests=len(tickets),
        completed=len(ok),
        shed=m["shed"], expired=m["expired"],
        shed_rate=m["shed_rate"],
        retries=m["retries"], degradations=m["degradations"],
        degraded_batches=m["degraded_batches"],
        recoveries=m["recoveries"], faults=m["faults"],
        health_final=m["health"],
        p50_ms=float(np.percentile(lat, 50) * 1e3) if len(lat) else None,
        p90_ms=float(np.percentile(lat, 90) * 1e3) if len(lat) else None,
        p99_ms=float(np.percentile(lat, 99) * 1e3) if len(lat) else None,
        max_ms=float(lat.max() * 1e3) if len(lat) else None,
        # the server's own histogram-estimated percentiles (interpolated,
        # fixed buckets) alongside the exact per-ticket numbers above
        hist_request_p50_ms=(m["request_p50_s"] * 1e3
                             if m["request_p50_s"] is not None else None),
        hist_request_p99_ms=(m["request_p99_s"] * 1e3
                             if m["request_p99_s"] is not None else None),
        queue_wait_p50_ms=(m["queue_wait_p50_s"] * 1e3
                           if m["queue_wait_p50_s"] is not None else None),
    )
    common.emit(f"serve_latency/{label}/p50",
                (row["p50_ms"] or 0.0) / 1e3,
                f"p99_ms={row['p99_ms']:.1f} shed={m['shed']} "
                f"expired={m['expired']} retries={m['retries']}")
    return row


def _drive_router(router: SpatialRouter, queries: np.ndarray,
                  arrivals: np.ndarray) -> list:
    """Open-loop load against the pool; blocks until every ticket is
    terminal (the router never drops a ticket — ok or failed, always)."""
    tickets = []
    t0 = time.perf_counter()
    try:
        for q, at in zip(queries, arrivals):
            lag = at - (time.perf_counter() - t0)
            if lag > 0:
                time.sleep(lag)
            tickets.append(router.submit(q, deadline_s=ROUTER_DEADLINE_S))
        for t in tickets:
            assert t.wait(timeout=60.0), "router dropped a ticket"
    finally:
        router.stop(drain=True, timeout=60.0)
    return tickets


def _summarize_router(label: str, router: SpatialRouter, tickets: list,
                      want: np.ndarray) -> dict:
    m = router.metrics()
    ok = [t for t in tickets if t.status == "ok"]
    # correctness gate: every routed response bit-equal to the oracle
    got = np.array([t.count for t in ok], dtype=np.int32)
    idx = [i for i, t in enumerate(tickets) if t.status == "ok"]
    np.testing.assert_array_equal(got, want[idx])
    lat = np.array([t.latency_s for t in ok], dtype=np.float64)
    row = dict(
        label=label,
        requests=len(tickets),
        completed=len(ok),
        failed=m["responses_failed"],
        failovers=m["failovers"],
        hedges=m["hedges"], hedge_wins=m["hedge_wins"],
        hedge_cancels=m["hedge_cancels"],
        ejections=m["ejections"],
        replicas_healthy=m["replicas_healthy"],
        replicas={name: snap["state"]
                  for name, snap in m["replicas"].items()},
        p50_ms=float(np.percentile(lat, 50) * 1e3) if len(lat) else None,
        p90_ms=float(np.percentile(lat, 90) * 1e3) if len(lat) else None,
        p99_ms=float(np.percentile(lat, 99) * 1e3) if len(lat) else None,
        max_ms=float(lat.max() * 1e3) if len(lat) else None,
    )
    common.emit(f"serve_latency/router_{label}/p50",
                (row["p50_ms"] or 0.0) / 1e3,
                f"p99_ms={row['p99_ms']:.1f} failed={row['failed']} "
                f"failovers={row['failovers']} hedges={row['hedges']}")
    return row


def _router_section(tree, queries: np.ndarray, want: np.ndarray,
                    replicas: int) -> dict:
    """clean vs replica-crash vs straggler vs straggler+hedged, one fresh
    pool per row over the identical arrival schedule."""
    arrivals = _poisson_arrivals(ROUTER_REQUESTS, ROUTER_RATE_QPS, seed=11)
    queries = queries[:ROUTER_REQUESTS]
    want = want[:ROUTER_REQUESTS]
    serve_cfg = ServeConfig(batch_size=128, max_queue=4096,
                            default_deadline_s=ROUTER_DEADLINE_S,
                            watchdog_s=5.0, max_retries=2,
                            backoff_base_s=0.005, backoff_cap_s=0.05,
                            crosscheck_every=0)

    def _router(hedge: bool = False) -> SpatialRouter:
        cfg = RouterConfig(num_replicas=replicas, failover_attempts=2,
                           attempt_timeout_s=2.0,
                           default_deadline_s=ROUTER_DEADLINE_S,
                           hedge=hedge, hedge_delay_s=0.05,
                           crosscheck_every=0, router_workers=16,
                           poll_interval_s=0.001)
        return SpatialRouter(
            lambda: beng.BroadcastEngine(tree, common.mesh1(),
                                         batch_size=serve_cfg.batch_size),
            config=cfg, serve_config=serve_cfg)

    section = {"replicas": replicas, "requests": ROUTER_REQUESTS,
               "rate_qps": ROUTER_RATE_QPS, "deadline_s": ROUTER_DEADLINE_S,
               "rows": []}

    router = _router()
    section["rows"].append(_summarize_router(
        "clean", router, _drive_router(router, queries, arrivals), want))

    # one replica crashes mid-run and never comes back: every request it
    # would have owned is rerouted; nothing is lost or answered twice
    router = _router()
    crash = chaos.ReplicaChaos(
        [chaos.Fault(chaos.REPLICA_CRASH, at_call=40, count=1, period=1)],
        seed=40).install(router.replicas()[0])
    row = _summarize_router(
        "replica_crash", router, _drive_router(router, queries, arrivals),
        want)
    row["fault_plan"] = crash.describe()
    assert row["failovers"] > 0, crash.describe()
    section["rows"].append(row)

    # persistent straggler on one replica's device step — first without
    # hedging (the straggler owns the tail), then the identical plan with
    # hedged retries on (acceptance: the hedge measurably cuts that p99)
    straggle_plan = [chaos.Fault(chaos.STRAGGLER, at_call=0, count=1,
                                 period=1, delay_s=STRAGGLE_DELAY_S)]
    rows = {}
    for label, hedge in (("straggler", False), ("straggler_hedged", True)):
        router = _router(hedge=hedge)
        inj = chaos.ChaosInjector(list(straggle_plan), seed=41)
        inj.install(router.replicas()[0].server)
        row = _summarize_router(
            label, router, _drive_router(router, queries, arrivals), want)
        row["fault_plan"] = inj.describe()
        rows[label] = row
        section["rows"].append(row)

    plain, hedged = rows["straggler"]["p99_ms"], \
        rows["straggler_hedged"]["p99_ms"]
    assert hedged < 0.9 * plain, (
        f"hedging did not cut the straggler p99: {hedged:.1f}ms vs "
        f"{plain:.1f}ms plain")
    section["hedge_p99_cut"] = dict(
        straggler_p99_ms=plain, hedged_p99_ms=hedged,
        speedup=plain / hedged)
    return section


def run(full: bool = False, replicas: int = 2) -> list[dict]:
    del full
    rects, queries, tree = _workload()
    from repro.kernels import ref
    want = ref.overlap_counts_np_chunked(queries, rects)
    arrivals = _poisson_arrivals(NUM_REQUESTS, ARRIVAL_RATE_QPS)
    cfg = ServeConfig(batch_size=256, max_queue=4096,
                      default_deadline_s=DEADLINE_S, watchdog_s=0.5,
                      max_retries=2, backoff_base_s=0.005,
                      backoff_cap_s=0.05, probe_every=2)

    report = {"workload": dict(
        num_rects=NUM_RECTS, requests=NUM_REQUESTS,
        arrival="poisson", rate_qps=ARRIVAL_RATE_QPS,
        deadline_s=DEADLINE_S)}

    # Trace the clean run (server construction/warmup stays untraced so
    # compile time never pollutes the breakdown): serve.form_batch spans on
    # the pump thread, serve.batch → stage/step/retrieve on the pool thread.
    srv = SpatialServer(beng.BroadcastEngine(tree, common.mesh1(),
                                             batch_size=cfg.batch_size), cfg)
    tracer = obs_trace.get_tracer()
    tracer.reset()
    tracer.enable()
    tickets = _drive(srv, queries, arrivals)
    tracer.disable()
    report["clean"] = _summarize("clean", srv, tickets, want)
    report["phases"] = obs_phases.breakdown(tracer.events())

    srv = SpatialServer(beng.BroadcastEngine(tree, common.mesh1(),
                                             batch_size=cfg.batch_size), cfg)
    chaos.ChaosInjector(list(FAULT_PLAN)).install(srv)
    report["chaos"] = _summarize(
        "chaos", srv, _drive(srv, queries, arrivals), want)

    report["router"] = _router_section(tree, queries, want, replicas)

    with open(OUT_PATH, "w") as fh:
        json.dump(report, fh, indent=2, default=float)
    common.emit("serve_latency/report", 0.0,
                f"wrote {os.path.abspath(OUT_PATH)}")
    return [report]


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--replicas", type=int, default=2,
                    help="pool size for the router section (default 2)")
    run(replicas=ap.parse_args().replicas)
