"""Tail latency of the fault-tolerant serving loop under Poisson arrival.

Two runs over the same deterministic arrival schedule:

* ``clean`` — healthy steady state; the fast (device) path serves every
  request.
* ``chaos`` — the same load with a deterministic fault plan injected at the
  step/placement seams (straggler, transient device loss, corrupted counts).
  The point of the row is the *shape* of the tail: p99 absorbs the watchdog
  + retry budget while p50 stays near the clean run, and shed/expired/
  degraded rates quantify what availability cost the faults extracted.

Writes ``BENCH_serve.json`` at the repo root and emits the usual CSV rows.

Usage: ``PYTHONPATH=src:. python -m benchmarks.serve_latency``
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks import common
from repro.core import engine as beng
from repro.core import rtree
from repro.data import datasets, spider
from repro.obs import phases as obs_phases
from repro.obs import trace as obs_trace
from repro.serve.spatial_serve import ServeConfig, SpatialServer
from repro.testing import chaos

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")

NUM_RECTS = 20_000
NUM_REQUESTS = 2_000
ARRIVAL_RATE_QPS = 2_000.0       # Poisson arrival intensity
DEADLINE_S = 2.0

FAULT_PLAN = (
    chaos.Fault(chaos.STRAGGLER, at_call=3, count=1, delay_s=0.75),
    chaos.Fault(chaos.DEVICE_LOSS, at_call=8, count=2),
    chaos.Fault(chaos.CORRUPT, at_call=14, count=1),
)


def _workload(seed: int = 5):
    rects = spider.uniform(NUM_RECTS, seed=seed)
    queries = datasets.make_queries(rects, 1.0, seed=seed + 1)
    reps = -(-NUM_REQUESTS // len(queries))
    queries = np.concatenate([queries] * reps)[:NUM_REQUESTS]
    tree = rtree.build_str_3level(
        rects, *rtree.choose_parameters(NUM_RECTS, 1))
    return rects, queries, tree


def _poisson_arrivals(n: int, rate_qps: float, seed: int = 7) -> np.ndarray:
    """Cumulative arrival offsets (seconds) for a Poisson process — fixed
    seed so the clean and chaos runs see the identical schedule."""
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_qps, size=n))


def _drive(srv: SpatialServer, queries: np.ndarray,
           arrivals: np.ndarray) -> list:
    """Open-loop load generator: submit each request at its scheduled
    arrival time regardless of how the server is keeping up."""
    srv.start()
    tickets = []
    t0 = time.perf_counter()
    try:
        for q, at in zip(queries, arrivals):
            lag = at - (time.perf_counter() - t0)
            if lag > 0:
                time.sleep(lag)
            tickets.append(srv.submit(q, deadline_s=DEADLINE_S))
    finally:
        srv.stop(drain=True, timeout=60.0)
    return tickets


def _summarize(label: str, srv: SpatialServer, tickets: list,
               want: np.ndarray) -> dict:
    m = srv.metrics()
    ok = [t for t in tickets if t.status == "ok"]
    # correctness gate: every completed response must be exact
    got = np.array([t.count for t in ok], dtype=np.int32)
    idx = [i for i, t in enumerate(tickets) if t.status == "ok"]
    np.testing.assert_array_equal(got, want[idx])
    lat = np.array([t.latency_s for t in ok], dtype=np.float64)
    row = dict(
        label=label,
        requests=len(tickets),
        completed=len(ok),
        shed=m["shed"], expired=m["expired"],
        shed_rate=m["shed_rate"],
        retries=m["retries"], degradations=m["degradations"],
        degraded_batches=m["degraded_batches"],
        recoveries=m["recoveries"], faults=m["faults"],
        health_final=m["health"],
        p50_ms=float(np.percentile(lat, 50) * 1e3) if len(lat) else None,
        p90_ms=float(np.percentile(lat, 90) * 1e3) if len(lat) else None,
        p99_ms=float(np.percentile(lat, 99) * 1e3) if len(lat) else None,
        max_ms=float(lat.max() * 1e3) if len(lat) else None,
        # the server's own histogram-estimated percentiles (interpolated,
        # fixed buckets) alongside the exact per-ticket numbers above
        hist_request_p50_ms=(m["request_p50_s"] * 1e3
                             if m["request_p50_s"] is not None else None),
        hist_request_p99_ms=(m["request_p99_s"] * 1e3
                             if m["request_p99_s"] is not None else None),
        queue_wait_p50_ms=(m["queue_wait_p50_s"] * 1e3
                           if m["queue_wait_p50_s"] is not None else None),
    )
    common.emit(f"serve_latency/{label}/p50",
                (row["p50_ms"] or 0.0) / 1e3,
                f"p99_ms={row['p99_ms']:.1f} shed={m['shed']} "
                f"expired={m['expired']} retries={m['retries']}")
    return row


def run(full: bool = False) -> list[dict]:
    del full
    rects, queries, tree = _workload()
    from repro.kernels import ref
    want = ref.overlap_counts_np_chunked(queries, rects)
    arrivals = _poisson_arrivals(NUM_REQUESTS, ARRIVAL_RATE_QPS)
    cfg = ServeConfig(batch_size=256, max_queue=4096,
                      default_deadline_s=DEADLINE_S, watchdog_s=0.5,
                      max_retries=2, backoff_base_s=0.005,
                      backoff_cap_s=0.05, probe_every=2)

    report = {"workload": dict(
        num_rects=NUM_RECTS, requests=NUM_REQUESTS,
        arrival="poisson", rate_qps=ARRIVAL_RATE_QPS,
        deadline_s=DEADLINE_S)}

    # Trace the clean run (server construction/warmup stays untraced so
    # compile time never pollutes the breakdown): serve.form_batch spans on
    # the pump thread, serve.batch → stage/step/retrieve on the pool thread.
    srv = SpatialServer(beng.BroadcastEngine(tree, common.mesh1(),
                                             batch_size=cfg.batch_size), cfg)
    tracer = obs_trace.get_tracer()
    tracer.reset()
    tracer.enable()
    tickets = _drive(srv, queries, arrivals)
    tracer.disable()
    report["clean"] = _summarize("clean", srv, tickets, want)
    report["phases"] = obs_phases.breakdown(tracer.events())

    srv = SpatialServer(beng.BroadcastEngine(tree, common.mesh1(),
                                             batch_size=cfg.batch_size), cfg)
    chaos.ChaosInjector(list(FAULT_PLAN)).install(srv)
    report["chaos"] = _summarize(
        "chaos", srv, _drive(srv, queries, arrivals), want)

    with open(OUT_PATH, "w") as fh:
        json.dump(report, fh, indent=2, default=float)
    common.emit("serve_latency/report", 0.0,
                f"wrote {os.path.abspath(OUT_PATH)}")
    return [report]


if __name__ == "__main__":
    run()
