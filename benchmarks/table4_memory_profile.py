"""Paper Table IV: aggregate memory-access profile of the query kernel.

The paper instruments DPU counters (539 GB read / 8 GB written / 19.3 G nodes
visited / 5.28 G rectangle tests / 24.4 GB/s attained) and concludes kernel
time tracks MRAM bytes, not compute.  We reproduce the *accounting*: exact
byte/test counts from the engine layout (every quantity below is closed-form
in the layout — the kernel streams each local leaf slice once per query
batch), validated against an instrumented reference run, plus attained-
bandwidth figures for the measured CPU path and the projected TPU path.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks import common
from repro.core import engine, rtree
from repro.data import datasets
from repro.kernels import ops, ref
from repro.obs import phases as obs_phases


def run(full: bool = False) -> list[dict]:
    name = "lakes"
    n = None if full else common.SCALED[name]
    devices = 2540 if full else 64
    rects = datasets.load(name, n=n)
    queries = datasets.make_queries(rects, 0.05, seed=43)
    nq = len(queries)
    b, f = rtree.choose_parameters(len(rects), devices)
    tree = rtree.build_str_3level(rects, b, f)
    layout = engine.shard_tree(tree, devices)
    nb = int(np.ceil(nq / 10_000))

    # --- closed-form access accounting (the paper's Table IV rows) --------
    # Phase 2 streams every local leaf rect once per query batch on every
    # device; Phase 1 reads the covering headers once per batch.
    leaf_bytes_read = layout.leaf_bytes * nb
    header_bytes_read = layout.cover_mbrs.nbytes * nb
    bytes_written = nq * 4                     # one count per query
    rect_tests = nq * layout.rects_per_device * layout.num_devices
    nodes_visited = nq * (layout.leaves_per_device * layout.num_devices
                          + layout.kmax * layout.num_devices)

    # measured per-device kernel time at this scale (one device's slice),
    # via the shared blocking harness (median over repeats, traced as a
    # single synthesized kernel span when the tracer is on)
    local = jnp.asarray(layout.leaf_rects_flat[: layout.rects_per_device])
    q = jnp.asarray(queries[:10_000])
    t_dev = obs_phases.measure(
        lambda: ops.overlap_counts(q, local, impl="xla"),
        name="table4_per_device_kernel", phase=obs_phases.KERNEL)
    dev_bytes = local.nbytes * 1  # streamed once per batch
    attained_bw = dev_bytes / t_dev

    rows = [dict(
        metric="total_bytes_read", value=leaf_bytes_read + header_bytes_read),
        dict(metric="total_bytes_written", value=bytes_written),
        dict(metric="rect_tests", value=rect_tests),
        dict(metric="nodes_visited", value=nodes_visited),
        dict(metric="per_device_kernel_s", value=t_dev),
        dict(metric="attained_bw_cpu_Bps", value=attained_bw),
        dict(metric="projected_tpu_kernel_s",
             value=dev_bytes / 819e9),
    ]
    common.emit("table4/lakes/per_device_kernel", t_dev,
                f"bytes_read={leaf_bytes_read + header_bytes_read} "
                f"rect_tests={rect_tests} "
                f"attained_bw={attained_bw/1e6:.2f}MB/s_cpu")
    # the paper's per-query streaming model (a DPU re-reads its slice per
    # query): 8 int-ops per 16-byte rect = 0.5 ops/byte → memory-bound,
    # the paper's Table IV conclusion.  Our batched kernel amortises each
    # streamed byte over the whole query batch (tile reuse) — intensity
    # rises by ~the batch/tile size, the central TPU-native improvement
    # (DESIGN.md §2).
    common.emit("table4/lakes/intensity_paper_model", 0.0,
                "ops_per_byte=0.50 memory_bound=True")
    reuse = rect_tests * 8 / (leaf_bytes_read + header_bytes_read)
    common.emit("table4/lakes/intensity_batched_kernel", 0.0,
                f"ops_per_byte={reuse:.0f} (query-batch tile reuse)")
    return rows


if __name__ == "__main__":
    run()
