"""Paper Table III + Fig 7: Broadcast PIM R-tree vs subtree-partitioned
baseline — kernel time and host→device communication volume.

The paper's central claim: subtree partitioning is communication-dominated
(distinct per-DPU serialized subtrees, re-staged as query volume grows) while
the broadcast design moves the shared prefix once and only streams compact
query batches.  We measure kernel times at container scale and evaluate the
byte-exact communication model of both engines (engine.transfer_stats), then
derive comm time on the paper's transfer bandwidth and on TPU ICI.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import engine, rtree, subtree
from repro.data import datasets
from repro.kernels import ref

# effective host→device bandwidths for the comm-time model
UPMEM_XFER_BW = 8e9     # ~aggregated UPMEM host→DPU broadcast bandwidth
TPU_ICI_BW = 50e9       # per-link ICI


def run(full: bool = False, fractions=(0.01, 0.05)) -> list[dict]:
    rows = []
    mesh = common.mesh1()
    num_virtual_devices = 256   # comm model evaluated at pod scale
    for name in ("sports", "lakes"):
        n = None if full else common.SCALED[name]
        rects = datasets.load(name, n=n)
        b, f = rtree.choose_parameters(len(rects), num_virtual_devices)
        tree = rtree.build_str_3level(rects, b, f)
        b_eng = engine.BroadcastEngine(tree, mesh, batch_size=10_000)
        s_eng = subtree.SubtreeEngine(rects, mesh, leaf_capacity=max(b, 32),
                                      batch_size=10_000)
        # comm volumes at pod scale (layout-exact, device-count-parametric)
        b_layout = engine.shard_tree(tree, num_virtual_devices)
        s_layout = subtree.build_layout(rects, num_virtual_devices,
                                        max(b, 32))
        for frac in fractions:
            queries = datasets.make_queries(rects, frac, seed=37)
            nq = len(queries)
            want = ref.overlap_counts_np(queries[:128], rects)
            assert (b_eng.query(queries[:128]) == want).all()
            assert (s_eng.query(queries[:128]) == want).all()

            t_b = common.time_fn(b_eng.query, queries, repeats=1, warmup=1)
            t_s = common.time_fn(s_eng.query, queries, repeats=1, warmup=1)

            # comm model at PAPER-scale query counts for this fraction —
            # the subtree re-staging cost compounds with batch count, which
            # container-scale query sets (1 batch) cannot exhibit
            paper_n = {"sports": 999_000, "lakes": 8_400_000}[name]
            paper_nq = int(paper_n * frac)
            nb = max(1, int(np.ceil(paper_nq / 10_000)))
            scale_up = paper_n / len(rects)
            bcast_bytes = int(b_layout.header_bytes
                              + b_layout.leaf_bytes * scale_up
                              + nb * 10_000 * 16)
            sub_bytes = int(s_layout.scatter_bytes * scale_up * nb
                            + nb * 10_000 * 16)
            rows.append(dict(
                dataset=name, queries=nq, frac=frac,
                broadcast_kernel_s=t_b, subtree_kernel_s=t_s,
                broadcast_comm_bytes=bcast_bytes,
                subtree_comm_bytes=sub_bytes,
                comm_ratio=sub_bytes / bcast_bytes,
                broadcast_comm_s_upmem=bcast_bytes / UPMEM_XFER_BW,
                subtree_comm_s_upmem=sub_bytes / UPMEM_XFER_BW,
            ))
            common.emit(f"table3/{name}/q{int(frac*100)}pct/broadcast",
                        t_b, f"comm_bytes={bcast_bytes}")
            common.emit(f"table3/{name}/q{int(frac*100)}pct/subtree",
                        t_s, f"comm_bytes={sub_bytes} "
                             f"comm_ratio={sub_bytes / bcast_bytes:.1f}x")
    return rows


if __name__ == "__main__":
    run()
