"""Paper Table V: energy comparison, reproduced as an analytic model.

No power meter exists in this container, so we replay the paper's own
methodology with its own measured power constants (Section V-G): active
system power 567–571 W during the CPU search phase, 590–601 W during DPU
kernel execution; energy = active power × phase time.  Phase times come from
our measured CPU/engine runs (Table II benchmark) at container scale, and
from the paper's runtimes at paper scale (--full replays the paper's Table V
numbers exactly, as a consistency check of the model).

A TPU-side energy model is reported alongside: pJ/byte HBM + pJ/flop
(v5e-class constants) applied to the dry-run roofline terms.
"""
from __future__ import annotations

from benchmarks import common, table2_cpu_vs_pim

CPU_POWER_W = 569.0      # paper: 567–571 W
DPU_POWER_W = 595.5      # paper: 590–601 W
TPU_J_PER_BYTE = 150e-12     # ~150 pJ per HBM byte (v5e-class)
TPU_J_PER_FLOP = 1.3e-12     # ~1.3 pJ per bf16 flop (v5e-class)


def tpu_energy_j(flops: float, hbm_bytes: float) -> float:
    """TPU-side energy model applied to dry-run roofline terms."""
    return flops * TPU_J_PER_FLOP + hbm_bytes * TPU_J_PER_BYTE

# Paper Table V runtimes (s) for the --full replay consistency check.
PAPER_RUNTIMES = {
    ("sports", 0.01): (0.41, 0.30), ("sports", 0.05): (2.00, 1.50),
    ("lakes", 0.01): (12.95, 3.61), ("lakes", 0.05): (64.35, 17.57),
    ("synthetic", 0.01): (23.52, 1.55), ("synthetic", 0.05): (117.75, 7.76),
}


def run(full: bool = False) -> list[dict]:
    rows = []
    if full:
        for (name, frac), (t_cpu, t_dpu) in PAPER_RUNTIMES.items():
            e_cpu = CPU_POWER_W * t_cpu / 1e3
            e_dpu = DPU_POWER_W * t_dpu / 1e3
            rows.append(dict(dataset=name, frac=frac, cpu_kj=e_cpu,
                             dpu_kj=e_dpu, efficiency=e_cpu / e_dpu))
            common.emit(f"table5/paper/{name}/q{int(frac*100)}pct", 0.0,
                        f"cpu_kJ={e_cpu:.2f} dpu_kJ={e_dpu:.2f} "
                        f"eff={e_cpu / e_dpu:.2f}x")
        return rows

    t2 = table2_cpu_vs_pim.run(fractions=(0.01,))
    for r in t2:
        e_cpu = CPU_POWER_W * r["cpu_par_s"]
        e_dpu = DPU_POWER_W * r["kernel_s"]
        rows.append(dict(dataset=r["dataset"], frac=r["frac"],
                         cpu_j=e_cpu, dpu_j=e_dpu,
                         efficiency=e_cpu / max(e_dpu, 1e-12)))
        common.emit(f"table5/{r['dataset']}/q{int(r['frac']*100)}pct", 0.0,
                    f"cpu_J={e_cpu:.2f} dpu_J={e_dpu:.2f} "
                    f"eff={e_cpu / max(e_dpu, 1e-12):.2f}x")
    return rows


if __name__ == "__main__":
    run()
