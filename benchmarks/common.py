"""Benchmark helpers: timing, CSV emission, scaled-down paper workloads.

The container is a single CPU core, so every benchmark runs a *scaled*
version of the paper's workload by default (the paper-scale datasets are
selected with --full).  All timings are wall-clock medians over repeats with
one warmup (jit) call excluded.
"""
from __future__ import annotations

import time
from typing import Callable

import numpy as np

import jax

from repro import compat

# scaled-down dataset sizes (paper sizes in comments)
SCALED = {
    "sports": 60_000,       # 999K
    "lakes": 200_000,       # 8.4M
    "synthetic": 400_000,   # 16M
}


def time_fn(fn: Callable, *args, repeats: int = 3, warmup: int = 1,
            **kw) -> float:
    """Median wall time of fn(*args) in seconds; blocks on jax outputs."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out) if hasattr(out, "block_until_ready") or \
            isinstance(out, jax.Array) else None
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        if isinstance(out, jax.Array):
            out.block_until_ready()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def emit(name: str, seconds: float, derived: str = "") -> None:
    """The harness-required CSV row: name,us_per_call,derived."""
    print(f"{name},{seconds * 1e6:.1f},{derived}")


def mesh1():
    return compat.make_mesh((1, 1), ("data", "model"))


def bench_step(eng):
    """Measurement bindings for either engine: a *non-donating* query step
    plus its placed operands and the replicated query sharding.

    Non-donating so one staged batch can be reused across timing repeats —
    what :func:`repro.obs.phases.measure_query_phases` requires.  Works for
    ``BroadcastEngine`` and ``SubtreeEngine`` (same step arity)."""
    if hasattr(eng, "leaf_coords"):             # BroadcastEngine
        from repro.core import engine as beng
        step = beng.make_query_step(eng.mesh, donate_queries=False)
        operands = (eng.leaf_coords, eng.rect_tile_mbrs, eng.cover_mbrs)
    else:                                       # SubtreeEngine
        from repro.core import subtree
        step = subtree.make_query_step(eng.mesh, donate_queries=False)
        operands = (eng.dev_coords, eng.dev_tile_mbrs, eng.dev_mbrs)
    return step, operands, eng._rep_sh
