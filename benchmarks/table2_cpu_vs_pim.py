"""Paper Table II: Broadcast PIM R-tree vs CPU baseline.

Reproduces the table's structure at container scale: CPU-seq / CPU-par
(Algorithm 1) against the broadcast engine's kernel and end-to-end time, per
dataset × query fraction.  On this 1-core container the engine's "kernel"
column measures the jitted XLA query step (the TPU kernel's stand-in); the
Pallas kernel itself is validated separately (interpret mode) and its TPU
behaviour is projected in §Roofline.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import cpu_baseline, engine, rtree
from repro.data import datasets
from repro.kernels import ref


DEVICES = 2540  # the paper's maximum stable DPU allocation


def run(full: bool = False, fractions=(0.01, 0.05)) -> list[dict]:
    """Kernel time at production scale is measured as PER-DEVICE work (the
    engines exchange nothing during the kernel — a device's kernel time IS
    the time to scan its own N/2540 leaf slice for the batch), plus the
    byte-exact communication model for end-to-end; CPU baselines are
    measured directly.  This mirrors the paper's comparison (2,540 DPUs vs
    an 8-thread CPU), which a 1-core container cannot time 1:1."""
    import jax.numpy as jnp
    from repro.kernels import ops as kops
    rows = []
    mesh = common.mesh1()
    for name in ("sports", "lakes", "synthetic"):
        n = None if full else common.SCALED[name]
        rects = datasets.load(name, n=n)
        b, f = rtree.choose_parameters(len(rects), DEVICES)
        tree = rtree.build_str_3level(rects, b, f)
        layout = engine.shard_tree(tree, DEVICES)
        local = jnp.asarray(
            layout.leaf_rects_flat[: layout.rects_per_device])
        eng = engine.BroadcastEngine(tree, mesh, batch_size=10_000)
        for frac in fractions:
            queries = datasets.make_queries(rects, frac, seed=31)
            nq = len(queries)

            t_seq = common.time_fn(
                cpu_baseline.sequential_query, tree, queries[: nq // 4],
                repeats=1, warmup=0) * 4
            t_par = common.time_fn(
                cpu_baseline.parallel_query, tree, queries, repeats=1,
                warmup=0)
            batch = np.asarray(queries[: min(nq, 10_000)], dtype=np.int32)
            q_dev = jnp.asarray(batch)
            t_kernel_batch = common.time_fn(
                lambda: kops.overlap_counts(q_dev, local, impl="xla"))
            nb = max(1, int(np.ceil(nq / 10_000)))
            t_kernel = t_kernel_batch * nb
            # e2e = kernel + query broadcast + count reduction (comm model)
            t_e2e = t_kernel + nb * (10_000 * 16 + 10_000 * 4) / 8e9

            # correctness cross-check on a sample (full engine)
            sample = queries[:256]
            want = ref.overlap_counts_np(sample, rects)
            got = eng.query(sample)
            assert (got == want).all()

            rows.append(dict(
                dataset=name, queries=nq, frac=frac, cpu_seq_s=t_seq,
                cpu_par_s=t_par, kernel_s=t_kernel, e2e_s=t_e2e,
                kernel_speedup=t_par / t_kernel, e2e_speedup=t_par / t_e2e))
            common.emit(
                f"table2/{name}/q{int(frac*100)}pct/kernel", t_kernel,
                f"kernel_speedup_vs_cpu_par={t_par / t_kernel:.2f}")
            common.emit(
                f"table2/{name}/q{int(frac*100)}pct/e2e", t_e2e,
                f"e2e_speedup_vs_cpu_par={t_par / t_e2e:.2f}")
    return rows


if __name__ == "__main__":
    run()
