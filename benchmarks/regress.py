"""Perf-regression harness for the device-resident query pipeline.

Runs on small synthetic data (container-friendly) and writes
``BENCH_pipeline.json`` at the repo root so the perf trajectory is tracked
PR-over-PR (DESIGN.md Sec 9).  Three groups:

* ``pipeline``  — A/B of the current BroadcastEngine against a vendored
  replica of the pre-cache engine (per-batch host staging, fixed 1024-query
  scan chunk, per-batch host sync).  The headline row is the sustained
  small-batch serving workload; a bulk paper-style batch row rides along.
  Outputs are asserted bit-equal before any timing is reported.
* ``build``     — vectorized STR bulk load vs the original per-leaf Python
  packing loops.
* ``tile_sweep`` / ``batch_breakdown`` — the fig9/fig10 benches scaled to
  the synthetic workload: modeled tile arithmetic intensity plus measured
  per-batch kernel time and modeled transfer slices.

Usage: ``PYTHONPATH=src:. python -m benchmarks.regress`` (or via
``benchmarks/run.py --only regress``).
"""
from __future__ import annotations

import json
import math
import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks import common
from repro import compat
from repro.core import engine as beng
from repro.core import rtree
from repro.core.types import EMPTY_RECT, SerializedRTree, mbr_of
from repro.data import datasets, spider
from repro.kernels import ref as kref
from repro.obs import phases as obs_phases
from repro.obs import trace as obs_trace

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_pipeline.json")

# A measured speedup may drop this fraction below the committed baseline
# before the harness refuses to record it (exit 1, baseline left untouched).
REGRESSION_TOLERANCE = 0.20

HOST_BW = 8e9    # UPMEM host link, fig10 model
ICI_BW = 50e9    # TPU interconnect, fig10 model

TILES = ((64, 256), (128, 512), (256, 1024), (512, 1024), (512, 2048),
         (1024, 2048))


# ---------------------------------------------------------------------------
# Vendored pre-cache engine (the seed's batch loop, verbatim semantics):
# Phase-1 mask materialized as a (Q, Kmax) boolean per batch, Phase-2 through
# the fixed-1024-chunk reference scan, one device_put + one forced host sync
# per batch.  Kept here — not in the library — purely as the regression
# baseline.
# ---------------------------------------------------------------------------


class _LegacyBroadcastEngine:
    def __init__(self, tree: SerializedRTree, mesh, *, batch_size: int):
        self.batch_size = int(batch_size)
        d = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
        self.layout = beng.shard_tree(tree, d)
        axes = tuple(mesh.axis_names)
        p_leaf = jax.sharding.PartitionSpec(axes)
        p_rep = jax.sharding.PartitionSpec()

        def shard_fn(local_rects, local_cover, queries):
            cover = local_cover.reshape(-1, 4)
            m = kref.rect_overlap(
                queries[:, None, :], cover[None, :, :]).any(axis=1)
            counts = kref.overlap_counts_ref(
                queries, local_rects, query_chunk=1024)
            counts = jnp.where(m, counts, 0).astype(jnp.int32)
            return jax.lax.psum(counts, axes)

        self._step = jax.jit(compat.shard_map(
            shard_fn, mesh=mesh, in_specs=(p_leaf, p_leaf, p_rep),
            out_specs=p_rep, check_vma=False))
        leaf_sh = jax.sharding.NamedSharding(mesh, p_leaf)
        self._rep_sh = jax.sharding.NamedSharding(mesh, p_rep)
        self.leaf_rects = jax.device_put(self.layout.leaf_rects_flat, leaf_sh)
        self.cover_mbrs = jax.device_put(self.layout.cover_mbrs, leaf_sh)

    def query(self, queries: np.ndarray) -> np.ndarray:
        queries = np.asarray(queries, dtype=np.int32)
        q, bs = queries.shape[0], self.batch_size
        out = np.empty(q, dtype=np.int32)
        for lo in range(0, q, bs):
            hi = min(lo + bs, q)
            batch = queries[lo:hi]
            if hi - lo < bs:
                batch = np.concatenate(
                    [batch, np.tile(EMPTY_RECT, (bs - (hi - lo), 1))])
            dev_batch = jax.device_put(batch, self._rep_sh)
            counts = self._step(self.leaf_rects, self.cover_mbrs, dev_batch)
            out[lo:hi] = np.asarray(counts)[: hi - lo]   # per-batch sync
        return out


def _legacy_build_str_3level(rects, leaf_capacity, fanout):
    """The seed's per-leaf/per-node Python packing loops, vendored for the
    build A/B."""
    rects = np.asarray(rects, dtype=np.int32)
    n = rects.shape[0]
    b, f = int(leaf_capacity), int(fanout)
    order = rtree.str_pack(rects, b)
    packed = rects[order]
    num_leaves = math.ceil(n / b)
    leaf_rects = np.tile(EMPTY_RECT, (num_leaves, b, 1))
    leaf_counts = np.zeros(num_leaves, dtype=np.int32)
    for j in range(num_leaves):
        lo, hi = j * b, min((j + 1) * b, n)
        leaf_rects[j, : hi - lo] = packed[lo:hi]
        leaf_counts[j] = hi - lo
    leaf_mbrs = np.tile(EMPTY_RECT, (num_leaves, 1))
    for j in range(num_leaves):
        if leaf_counts[j]:
            leaf_mbrs[j] = mbr_of(leaf_rects[j, : leaf_counts[j]])
    l1_order = rtree.str_pack(leaf_mbrs, f)
    leaf_rects = leaf_rects[l1_order]
    leaf_counts = leaf_counts[l1_order]
    leaf_mbrs = leaf_mbrs[l1_order]
    num_l1 = math.ceil(num_leaves / f)
    l1_mbrs = np.tile(EMPTY_RECT, (num_l1, 1))
    l1_child_start = np.zeros(num_l1, dtype=np.int32)
    l1_child_count = np.zeros(num_l1, dtype=np.int32)
    for i in range(num_l1):
        lo, hi = i * f, min((i + 1) * f, num_leaves)
        l1_child_start[i] = lo
        l1_child_count[i] = hi - lo
        l1_mbrs[i] = mbr_of(leaf_mbrs[lo:hi])
    return SerializedRTree(
        root_mbr=mbr_of(l1_mbrs), l1_mbrs=l1_mbrs,
        l1_child_start=l1_child_start, l1_child_count=l1_child_count,
        leaf_mbrs=leaf_mbrs, leaf_counts=leaf_counts, leaf_rects=leaf_rects)


def _median_time(fn, repeats=3):
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _pipeline_ab(tree, rects, queries, mesh, batch_size, label, repeats=3):
    legacy = _LegacyBroadcastEngine(tree, mesh, batch_size=batch_size)
    current = beng.BroadcastEngine(tree, mesh, batch_size=batch_size)
    # warmup / compile, and correctness gate for the A/B itself
    want = legacy.query(queries)
    got = current.query(queries)
    np.testing.assert_array_equal(got, want)
    nq = len(queries)
    t_legacy = _median_time(lambda: legacy.query(queries), repeats)
    t_new = _median_time(lambda: current.query(queries), repeats)
    row = dict(
        bench=label, batch_size=batch_size, num_queries=nq,
        num_rects=int(rects.shape[0]),
        legacy_s=t_legacy, new_s=t_new,
        legacy_qps=nq / t_legacy, new_qps=nq / t_new,
        speedup=t_legacy / t_new,
    )
    common.emit(f"regress/{label}/legacy", t_legacy,
                f"qps={row['legacy_qps']:.0f}")
    common.emit(f"regress/{label}/new", t_new,
                f"qps={row['new_qps']:.0f} speedup={row['speedup']:.2f}x")
    return row, current


def _load_baseline() -> dict | None:
    """The committed BENCH_pipeline.json, read before this run overwrites
    it.  ``None`` (first run / unreadable file) disables the gate."""
    try:
        with open(OUT_PATH) as fh:
            return json.load(fh)
    except (OSError, ValueError):
        return None


def _regression_failures(report: dict, baseline: dict | None,
                         tolerance: float = REGRESSION_TOLERANCE
                         ) -> list[str]:
    """Rows whose speedup fell more than ``tolerance`` below the committed
    baseline, as human-readable failure lines (empty = gate passes)."""
    if not baseline:
        return []
    fails = []
    base_rows = {r["bench"]: r for r in baseline.get("pipeline", [])}
    for row in report.get("pipeline", []):
        base = base_rows.get(row["bench"])
        if not base:
            continue
        floor = base["speedup"] * (1.0 - tolerance)
        if row["speedup"] < floor:
            fails.append(
                f"{row['bench']}: speedup {row['speedup']:.3f}x fell below "
                f"floor {floor:.3f}x (committed {base['speedup']:.3f}x "
                f"- {tolerance:.0%})")
    new_b, old_b = report.get("build"), baseline.get("build")
    if new_b and old_b:
        floor = old_b["speedup"] * (1.0 - tolerance)
        if new_b["speedup"] < floor:
            fails.append(
                f"build: speedup {new_b['speedup']:.3f}x fell below floor "
                f"{floor:.3f}x (committed {old_b['speedup']:.3f}x "
                f"- {tolerance:.0%})")
    return fails


def _pallint_gate() -> None:
    """Refuse to record a perf baseline from a doctrine-violating tree.

    A benchmark number taken while the hot path silently syncs or retraces
    would poison the PR-over-PR trajectory, so the lint pass must be clean
    before BENCH_pipeline.json is written."""
    from repro.analysis.pallint.core import lint_paths, registry, render_human

    repo = os.path.join(os.path.dirname(__file__), "..")
    findings = lint_paths([os.path.join(repo, "src"),
                           os.path.join(repo, "benchmarks")])
    if findings:
        raise SystemExit(
            "pallint gate failed; not recording a perf baseline:\n"
            + render_human(findings, registry()))


def run(full: bool = False) -> list[dict]:
    _pallint_gate()
    n = 100_000 if full else 20_000
    nq = 8192
    rects = spider.uniform(n, seed=5)
    queries = datasets.make_queries(rects, 1.0, seed=6)
    queries = np.concatenate([queries] * math.ceil(nq / len(queries)))[:nq]
    mesh = common.mesh1()
    tree = rtree.build_str_3level(rects, *rtree.choose_parameters(n, 1))

    report: dict = {"workload": dict(num_rects=n, num_queries=nq,
                                     distribution="uniform", seed=5)}

    # --- pipeline A/B: sustained serving batches (headline) + bulk batch ---
    serving, eng = _pipeline_ab(tree, rects, queries, mesh,
                                batch_size=256, label="pipeline_serving")
    bulk, _ = _pipeline_ab(tree, rects, queries, mesh,
                           batch_size=4096, label="pipeline_bulk")
    # Investigated (see DESIGN.md Sec 9): at bs=4096 both paths are
    # compute-bound on near-identical scan kernels (~90% of end-to-end is
    # device compute), so the metadata-cache win — per-batch staging and
    # host sync — is amortized to noise and the A/B ratio hovers around
    # 1.0x run-to-run.  The committed 0.85x was one draw from that band,
    # not a pipeline regression; bs=256 serving is the headline row.
    bulk["note"] = (
        "compute-bound at bulk batch size: both engines spend ~90% of "
        "end-to-end in near-identical scan kernels, so speedup ~= 1.0x "
        "+/- measurement noise; the cached-metadata win (per-batch "
        "staging/sync) only shows at serving batch sizes")
    report["pipeline"] = [serving, bulk]

    # --- host-side build: vectorized vs per-leaf Python loops --------------
    b, f = rtree.choose_parameters(n, 256)
    t_build_legacy = _median_time(
        lambda: _legacy_build_str_3level(rects, b, f), repeats=2)
    t_build_new = _median_time(
        lambda: rtree.build_str_3level(rects, b, f), repeats=2)
    report["build"] = dict(
        num_rects=n, leaf_capacity=b, fanout=f,
        legacy_s=t_build_legacy, new_s=t_build_new,
        speedup=t_build_legacy / t_build_new)
    common.emit("regress/build/legacy", t_build_legacy, "")
    common.emit("regress/build/new", t_build_new,
                f"speedup={t_build_legacy / t_build_new:.2f}x")

    # --- fig9-style tile sweep (modeled intensity, scaled) -----------------
    tile_rows = []
    for tq, tr in TILES:
        tile_bytes = (tq + tr) * 16
        tile_ops = tq * tr * 8
        tile_rows.append(dict(tq=tq, tr=tr,
                              intensity_ops_per_byte=tile_ops / tile_bytes,
                              vmem_kb=(tile_bytes + tq * tr // 8) / 1024))
    report["tile_sweep"] = tile_rows

    # --- fig10-style batch breakdown on the synthetic workload ------------
    # Blocking per-batch slices from the shared obs harness (the same
    # helper fig10_batch_breakdown.py uses, so the numbers agree by
    # construction); UPMEM/ICI transfer slices stay modeled.
    bs = 4096
    batch = np.asarray(queries[:bs], np.int32)
    step, operands, rep_sh = common.bench_step(eng)
    slices = obs_phases.measure_query_phases(step, operands, batch, rep_sh)
    t_kernel = slices["kernel_s"]
    q_bytes, r_bytes = batch.nbytes, batch.shape[0] * 4
    report["batch_breakdown"] = dict(
        batch_size=bs, kernel_s=t_kernel,
        h2d_measured_s=slices["h2d_s"],
        d2h_measured_s=slices["d2h_s"],
        query_transfer_upmem_s=q_bytes / HOST_BW,
        result_retrieval_upmem_s=r_bytes / HOST_BW,
        query_transfer_tpu_s=q_bytes / ICI_BW,
        result_retrieval_tpu_s=r_bytes / ICI_BW,
        transfer_model=eng.transfer_stats(nq))
    common.emit("regress/batch_breakdown/kernel", t_kernel,
                f"batch={bs}")

    report["phases"] = _phase_accounting(rects, queries, mesh, n, nq)

    _gate_and_record(report)

    # --- query-surface throughput (ids/knn/radius/aggregate) ---------------
    # Separate baseline file (BENCH_query.json), same no-downward-ratchet
    # discipline; rides this entry point so one `-m benchmarks.regress`
    # invocation gates the whole perf trajectory.
    from benchmarks import query_surface
    q_report = query_surface.measure(full=full)
    query_surface.gate_and_record(q_report)
    return [report, q_report]


def _phase_accounting(rects, queries, mesh, n, nq) -> dict:
    """One traced end-to-end pipeline run folded into Fig-10 fractions.

    Build + placement + a steady-state streamed run are traced through the
    global tracer (DESIGN.md Sec 12); the pipelined stream hides kernel wait
    in its end-of-set sync, so per-batch device slices come from the blocking
    harness and :func:`repro.obs.phases.compose_pipeline` folds both views
    into end-to-end fractions.  The compile happens on an untraced warmup
    call so jit time never pollutes the breakdown.
    """
    bs = 256
    tracer = obs_trace.get_tracer()
    tracer.reset()
    tracer.enable()
    tree = rtree.build_str_3level(rects, *rtree.choose_parameters(n, 1))
    eng = beng.BroadcastEngine(tree, mesh, batch_size=bs)
    tracer.disable()
    eng.query(queries[:bs])                     # untraced warmup: jit compile
    tracer.enable()
    t0 = time.perf_counter()
    eng.query(queries)
    stream_wall_s = time.perf_counter() - t0
    step, operands, rep_sh = common.bench_step(eng)
    per_batch = obs_phases.measure_query_phases(
        step, operands, np.asarray(queries[:bs], np.int32), rep_sh)
    tracer.disable()
    events = tracer.events()
    composed = obs_phases.compose_pipeline(
        build_s=obs_phases.span_seconds(events, "build_str_3level"),
        place_s=obs_phases.span_seconds(events, "place"),
        per_batch=per_batch,
        num_batches=math.ceil(nq / bs),
        stream_wall_s=stream_wall_s)
    fr = composed["fractions"]
    common.emit("regress/phases/pipeline", 0.0,
                f"build={fr['build']:.3f} h2d={fr['h2d']:.3f} "
                f"kernel={fr['kernel']:.3f} d2h={fr['d2h']:.3f} "
                f"host={fr['host']:.3f}")
    return dict(
        batch_size=bs,
        breakdown=obs_phases.breakdown(events),
        per_batch=per_batch,
        pipeline=composed,
        derived=obs_phases.derived_stats(eng.layout, nq, bs))


def _gate_and_record(report: dict) -> None:
    """Apply the regression gate, then persist the new baseline.  On a
    gate failure: exit non-zero and leave the committed baseline untouched
    so the regressing run cannot ratchet the floor downward."""
    fails = _regression_failures(report, _load_baseline())
    if fails:
        for line in fails:
            common.emit("regress/GATE-FAIL", 0.0, line)
        raise SystemExit(
            "perf regression gate failed; baseline NOT overwritten:\n  "
            + "\n  ".join(fails))
    with open(OUT_PATH, "w") as fh:
        json.dump(report, fh, indent=2, default=float)
    common.emit("regress/report", 0.0, f"wrote {os.path.abspath(OUT_PATH)}")


if __name__ == "__main__":
    run()
