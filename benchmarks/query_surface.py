"""Throughput benchmark for the repro.query surface (ids/kNN/radius/agg).

Runs every query kind through the full offline engine path — validation,
Morton ordering, ``stream_batches`` micro-batching, ``SpatialResult``
assembly — on a scaled synthetic workload and records per-kind throughput
plus overflow accounting into ``BENCH_query.json`` at the repo root.  The
file is a committed perf baseline: ``benchmarks/regress.py`` gates each
kind's queries/s against it (with a wider tolerance than the pipeline A/B —
absolute throughput is noisier than a same-process speedup ratio).

Correctness is asserted against the NumPy oracles on a workload slice
before any timing is reported, so a number can never be recorded for a
wrong kernel.

Usage: ``PYTHONPATH=src:. python -m benchmarks.query_surface`` (or via
``benchmarks/run.py --only query_surface``; ``regress`` runs it too).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks import common
from repro.core import engine as beng
from repro.core import rtree
from repro.data import datasets, spider
from repro.query import oracle

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_query.json")

# Absolute qps across container runs wobbles more than an in-process A/B
# ratio; the gate floor is correspondingly wider than regress's 20%.
QUERY_TOLERANCE = 0.35

KCAP = 64
KNN_K = 8
VERIFY_Q = 256       # oracle-checked slice (the full set times the bench)


def _workload(full: bool):
    n = 100_000 if full else 20_000
    nq = 8192 if full else 2048
    rects = spider.uniform(n, seed=5)
    queries = datasets.make_queries(rects, 1.0, seed=6)
    reps = -(-nq // len(queries))
    queries = np.concatenate([queries] * reps)[:nq]
    rng = np.random.default_rng(7)
    points = rng.integers(0, spider.SCALE, (nq, 2)).astype(np.int32)
    radii = rng.integers(0, spider.SCALE // 16, nq).astype(np.int32)
    tree = rtree.build_str_3level(rects, *rtree.choose_parameters(n, 1))
    return n, rects, queries, points, radii, tree


def _verify(eng, queries, points, radii) -> None:
    """Oracle gate on a slice: bit-exact ids/knn/radius, toleranced sums."""
    pr, pi = eng.placed_rects, eng.placed_ids
    q, p, r = queries[:VERIFY_Q], points[:VERIFY_Q], radii[:VERIFY_Q]
    res = eng.query_ids(q, kcap=KCAP)
    w_ids, w_cnt, w_ov = oracle.ids_oracle(q, pr, pi, kcap=KCAP)
    np.testing.assert_array_equal(res.ids, w_ids)
    np.testing.assert_array_equal(res.count, w_cnt)
    np.testing.assert_array_equal(res.overflow, w_ov)
    res = eng.query_knn(p, k=KNN_K)
    w_d, w_i = oracle.knn_oracle(p, pr, pi, k=KNN_K)
    np.testing.assert_array_equal(res.ids, w_i)
    np.testing.assert_array_equal(res.distances, w_d)
    res = eng.query_radius(p, r, kcap=KCAP)
    w_ids, w_cnt, _ = oracle.radius_oracle(p, r, pr, pi, kcap=KCAP)
    np.testing.assert_array_equal(res.ids, w_ids)
    np.testing.assert_array_equal(res.count, w_cnt)
    res = eng.query_aggregate(q)
    w_cnt, w_sums, w_bbox = oracle.aggregate_oracle(q, pr)
    np.testing.assert_array_equal(res.count, w_cnt)
    np.testing.assert_array_equal(res.bbox, w_bbox)
    np.testing.assert_allclose(res.aggregates["sums"], w_sums,
                               rtol=oracle.AGG_RTOL, atol=oracle.AGG_ATOL)


def _median_time(fn, repeats: int = 3) -> float:
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def measure(full: bool = False) -> dict:
    n, rects, queries, points, radii, tree = _workload(full)
    nq = len(queries)
    eng = beng.BroadcastEngine(tree, common.mesh1(), batch_size=512)
    _verify(eng, queries, points, radii)

    rows = []

    def bench(kind, fn, result, extra=None):
        t = _median_time(fn)
        row = dict(kind=kind, num_queries=nq, wall_s=t, qps=nq / t)
        if result.overflow is not None:
            ov = result.overflow
            row.update(
                kcap=KCAP,
                overflow_queries=int((ov > 0).sum()),
                overflow_rate=float((ov > 0).mean()),
                overflow_ids_total=int(ov.sum()),
            )
        if extra:
            row.update(extra)
        rows.append(row)
        common.emit(f"query_surface/{kind}", t,
                    f"qps={row['qps']:.0f}"
                    + (f" overflow_rate={row['overflow_rate']:.3f}"
                       if "overflow_rate" in row else ""))

    res_ids = eng.query_ids(queries, kcap=KCAP)          # warmup/compile
    bench("ids", lambda: eng.query_ids(queries, kcap=KCAP), res_ids)
    res_knn = eng.query_knn(points, k=KNN_K)
    bench("knn", lambda: eng.query_knn(points, k=KNN_K), res_knn,
          extra=dict(k=KNN_K))
    res_rad = eng.query_radius(points, radii, kcap=KCAP)
    bench("radius", lambda: eng.query_radius(points, radii, kcap=KCAP),
          res_rad)
    res_agg = eng.query_aggregate(queries)
    bench("aggregate", lambda: eng.query_aggregate(queries), res_agg)

    return {
        "workload": dict(num_rects=n, num_queries=nq, kcap=KCAP, knn_k=KNN_K,
                         distribution="uniform", seed=5,
                         verified_queries=VERIFY_Q),
        "kinds": rows,
    }


def load_baseline() -> dict | None:
    """The committed BENCH_query.json; ``None`` disables the gate."""
    try:
        with open(OUT_PATH) as fh:
            return json.load(fh)
    except (OSError, ValueError):
        return None


def regression_failures(report: dict, baseline: dict | None,
                        tolerance: float = QUERY_TOLERANCE) -> list[str]:
    """Kinds whose throughput fell more than ``tolerance`` below the
    committed baseline, as human-readable lines (empty = gate passes)."""
    if not baseline:
        return []
    fails = []
    base_rows = {r["kind"]: r for r in baseline.get("kinds", [])}
    for row in report.get("kinds", []):
        base = base_rows.get(row["kind"])
        if not base:
            continue
        floor = base["qps"] * (1.0 - tolerance)
        if row["qps"] < floor:
            fails.append(
                f"query_{row['kind']}: {row['qps']:.0f} qps fell below "
                f"floor {floor:.0f} (committed {base['qps']:.0f} "
                f"- {tolerance:.0%})")
    return fails


def gate_and_record(report: dict) -> None:
    """Gate against the committed baseline and persist on pass; on failure
    exit non-zero and leave BENCH_query.json untouched (no downward
    ratchet), mirroring regress's pipeline gate."""
    fails = regression_failures(report, load_baseline())
    if fails:
        for line in fails:
            common.emit("query_surface/GATE-FAIL", 0.0, line)
        raise SystemExit(
            "query-surface regression gate failed; baseline NOT "
            "overwritten:\n  " + "\n  ".join(fails))
    with open(OUT_PATH, "w") as fh:
        json.dump(report, fh, indent=2, default=float)
    common.emit("query_surface/report", 0.0,
                f"wrote {os.path.abspath(OUT_PATH)}")


def run(full: bool = False) -> list[dict]:
    report = measure(full)
    gate_and_record(report)
    return [report]


if __name__ == "__main__":
    run()
