"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Default scale fits this
container (scaled datasets, 1 device); ``--full`` selects paper-scale
dataset sizes, and the dry-run/roofline cells are produced by
``python -m repro.launch.dryrun --all`` (512 fake devices, separate process
by design — benches must see one device)."""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale dataset sizes (slow)")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args()

    from benchmarks import (fig8_strong_scaling, fig9_tile_sweep,
                            fig10_batch_breakdown, query_surface, regress,
                            serve_latency, table2_cpu_vs_pim,
                            table3_broadcast_vs_subtree,
                            table4_memory_profile, table5_energy)
    benches = {
        "table2": table2_cpu_vs_pim.run,
        "table3": table3_broadcast_vs_subtree.run,
        "table4": table4_memory_profile.run,
        "table5": table5_energy.run,
        "fig8": fig8_strong_scaling.run,
        "fig9": fig9_tile_sweep.run,
        "fig10": fig10_batch_breakdown.run,
        "regress": regress.run,
        "serve_latency": serve_latency.run,
        "query_surface": query_surface.run,
    }
    selected = (args.only.split(",") if args.only else list(benches))
    print("name,us_per_call,derived")
    failures = []
    for name in selected:
        try:
            benches[name](full=args.full)
        except Exception:
            traceback.print_exc()
            failures.append(name)
    if failures:
        print(f"FAILED: {failures}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
