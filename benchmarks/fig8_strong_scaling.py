"""Paper Fig 8: strong scaling of the broadcast engine over device count.

Fixed dataset + query set; device count swept 512 → 2540 in the paper.  The
container has one core, so per-device *work* is measured directly: the
engine's kernel at D devices scans N/D leaf rects per device, and the
measured kernel time of a leaf slice of that size (same query batch) IS the
per-device kernel time — the engines exchange nothing during the kernel, so
strong scaling is work-scaling plus the fixed communication model, exactly
the decomposition the paper's Fig 8 makes (kernel speedup grows faster than
end-to-end because fixed host↔device costs do not shrink)."""
from __future__ import annotations

import math

import numpy as np

import jax

from benchmarks import common
from repro.core import engine, rtree
from repro.data import datasets
from repro.kernels import ops

DEVICE_COUNTS = (8, 32, 128, 512, 1024, 2540)


def run(full: bool = False) -> list[dict]:
    name = "lakes"
    n = None if full else common.SCALED[name]
    rects = datasets.load(name, n=n)
    queries = datasets.make_queries(rects, 0.05, seed=41)[:2048]
    rows = []
    base_t = None
    for d in DEVICE_COUNTS:
        b, f = rtree.choose_parameters(len(rects), d)
        tree = rtree.build_str_3level(rects, b, f)
        layout = engine.shard_tree(tree, d)
        # one device's leaf slice
        local = layout.leaf_rects_flat[: layout.rects_per_device]
        q = jax.numpy.asarray(queries)
        r = jax.numpy.asarray(local)
        t_kernel = common.time_fn(
            lambda: ops.overlap_counts(q, r, impl="xla"))
        # per-batch comm model: queries broadcast + counts reduced
        comm_bytes = queries.shape[0] * 16 + queries.shape[0] * 4
        t_comm = comm_bytes / 8e9 + 5e-6 * math.log2(d)  # bw + hop latency
        t_e2e = t_kernel + t_comm
        if base_t is None:
            base_t = (t_kernel, t_e2e, d)
        rows.append(dict(
            devices=d, kernel_s=t_kernel, e2e_s=t_e2e,
            kernel_speedup=base_t[0] / t_kernel * 1.0,
            e2e_speedup=base_t[1] / t_e2e))
        common.emit(f"fig8/lakes/devices{d}", t_kernel,
                    f"kernel_speedup_vs_{base_t[2]}dev="
                    f"{base_t[0] / t_kernel:.2f}")
    return rows


if __name__ == "__main__":
    run()
