"""Test-suite bootstrap.

The container image does not ship ``hypothesis`` and nothing may be pip
installed, so when the real package is missing we register a minimal,
deterministic stand-in exposing the tiny subset the suite uses
(``given``/``settings``/``strategies.integers``).  Property tests then run a
fixed number of seeded random examples — less powerful than real shrinking,
but the invariants still get exercised and the suite stays green.
"""
from __future__ import annotations


import sys
import types

import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running subprocess tests")
    config.addinivalue_line(
        "markers", "chaos: fault-injection serving tests (dedicated CI job)")
    config.addinivalue_line(
        "markers", "chaos_router: replica-level fault-injection router tests "
        "(dedicated CI job)")


try:  # pragma: no cover - exercised only when hypothesis is installed
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    class _IntStrategy:
        def __init__(self, lo, hi):
            self.lo, self.hi = int(lo), int(hi)

        def sample(self, rng):
            return int(rng.integers(self.lo, self.hi + 1))

    def _integers(min_value, max_value):
        return _IntStrategy(min_value, max_value)

    def _given(**strats):
        def deco(fn):
            def run(*args, **kwargs):
                rng = np.random.default_rng(0xC0FFEE)
                n = getattr(run, "_max_examples", 20)
                for _ in range(n):
                    drawn = {k: s.sample(rng) for k, s in strats.items()}
                    fn(*args, **drawn, **kwargs)
            # keep the test's name but NOT __wrapped__ — pytest would
            # introspect the original signature and demand fixtures for
            # the drawn parameters
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            run._max_examples = getattr(fn, "_max_examples", 20)
            return run
        return deco

    def _settings(max_examples=20, deadline=None, **_kw):
        del deadline

        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
