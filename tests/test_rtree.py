"""Unit + property tests for host-side R-tree construction (paper Sec III)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import cpu_baseline, rtree
from repro.core.types import EMPTY_RECT, rect_overlap_np
from repro.data import spider
from repro.kernels import ref


def _rand_rects(n, seed=0, scale=1000):
    rng = np.random.default_rng(seed)
    lo = rng.integers(0, scale, (n, 2))
    sz = rng.integers(0, scale // 10 + 1, (n, 2))
    return np.concatenate([lo, lo + sz], axis=1).astype(np.int32)


# ---------------------------------------------------------------------------
# STR 3-level construction invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,b,f", [(1, 4, 2), (7, 4, 2), (64, 8, 4),
                                   (1000, 16, 8), (999, 7, 3)])
def test_str_tree_invariants(n, b, f):
    rects = _rand_rects(n, seed=n)
    t = rtree.build_str_3level(rects, leaf_capacity=b, fanout=f)

    # Every input rect appears in exactly one leaf slot.
    got = []
    for j in range(t.num_leaves):
        c = int(t.leaf_counts[j])
        assert 0 < c <= b
        got.append(np.asarray(t.leaf_rects)[j, :c])
        # padding slots are the sentinel
        assert (np.asarray(t.leaf_rects)[j, c:] == EMPTY_RECT).all()
    got = np.concatenate(got)
    assert got.shape == rects.shape
    assert (np.sort(got.view([("", np.int32)] * 4), axis=0)
            == np.sort(rects.view([("", np.int32)] * 4), axis=0)).all()

    # Leaf MBRs contain their rects; level-1 MBRs contain child leaf MBRs;
    # root contains everything.
    for j in range(t.num_leaves):
        c = int(t.leaf_counts[j])
        r = np.asarray(t.leaf_rects)[j, :c]
        m = np.asarray(t.leaf_mbrs)[j]
        assert (r[:, 0] >= m[0]).all() and (r[:, 2] <= m[2]).all()
        assert (r[:, 1] >= m[1]).all() and (r[:, 3] <= m[3]).all()
    starts = np.asarray(t.l1_child_start)
    counts = np.asarray(t.l1_child_count)
    # BFS contiguity: child ranges exactly partition [0, L)
    assert starts[0] == 0
    assert (starts[1:] == starts[:-1] + counts[:-1]).all()
    assert starts[-1] + counts[-1] == t.num_leaves
    for i in range(t.num_l1):
        m = np.asarray(t.l1_mbrs)[i]
        ch = np.asarray(t.leaf_mbrs)[starts[i]: starts[i] + counts[i]]
        assert (ch[:, 0] >= m[0]).all() and (ch[:, 2] <= m[2]).all()
        assert counts[i] <= f
    rm = np.asarray(t.root_mbr)
    l1 = np.asarray(t.l1_mbrs)
    assert (l1[:, 0] >= rm[0]).all() and (l1[:, 3] <= rm[3]).all()


def test_sn_records_layout():
    rects = _rand_rects(300, seed=3)
    t = rtree.build_str_3level(rects, leaf_capacity=8, fanout=4)
    sn = rtree.to_sn_records(t)
    # leaf level begins at 1 + SN[0].count (paper Sec III-C.2)
    leaf_base = 1 + int(sn[0]["count"])
    assert leaf_base == 1 + t.num_l1
    assert (sn[leaf_base:]["isLeaf"] == 1).all()
    assert (sn[1:leaf_base]["isLeaf"] == 0).all()
    # level-1 children indices point into the leaf region contiguously
    for i in range(t.num_l1):
        cc = int(sn[1 + i]["count"])
        ch = sn[1 + i]["children"][:cc]
        assert (np.diff(ch) == 1).all()
        assert ch.min() >= leaf_base


def test_choose_parameters_three_levels():
    for n in [1000, 999_000, 8_400_000, 16_000_000]:
        for d in [8, 256, 512, 2540]:
            b, f = rtree.choose_parameters(n, d)
            leaves = -(-n // b)
            assert leaves >= min(d, n)          # work for every device
            c1 = -(-leaves // f)
            assert 1 <= c1 <= 512               # compact broadcast prefix


# ---------------------------------------------------------------------------
# Query correctness: CPU baseline == brute force
# ---------------------------------------------------------------------------

def test_cpu_baseline_matches_bruteforce():
    rects = _rand_rects(500, seed=5)
    queries = _rand_rects(64, seed=6, scale=1200)
    t = rtree.build_str_3level(rects, leaf_capacity=8, fanout=4)
    expected = ref.overlap_counts_np(queries, rects)
    assert (cpu_baseline.sequential_query(t, queries) == expected).all()
    assert (cpu_baseline.parallel_query(t, queries, num_threads=4,
                                        chunk_size=7) == expected).all()


def test_topdown_matches_bruteforce():
    rects = _rand_rects(400, seed=8)
    queries = _rand_rects(32, seed=9, scale=1200)
    root = rtree.build_fanout_constrained(rects, num_devices=8, leaf_capacity=16)
    subs = rtree.subtree_partitions(root, 8)
    assert sum(s.count_rects() for s in subs) == 400
    expected = ref.overlap_counts_np(queries, rects)
    got = np.array([
        sum(cpu_baseline.search_topdown(s, q) for s in subs) for q in queries
    ])
    assert (got == expected).all()


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 120),
    b=st.integers(1, 9),
    f=st.integers(2, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_counts_match(n, b, f, seed):
    rng = np.random.default_rng(seed)
    lo = rng.integers(-50, 50, (n, 2))
    sz = rng.integers(0, 30, (n, 2))        # degenerate (zero-area) allowed
    rects = np.concatenate([lo, lo + sz], axis=1).astype(np.int32)
    queries = rects[rng.choice(n, size=min(n, 16))].copy()
    t = rtree.build_str_3level(rects, leaf_capacity=b, fanout=f)
    expected = ref.overlap_counts_np(queries, rects)
    got = cpu_baseline.sequential_query(t, queries)
    assert (got == expected).all()
    # a query equal to a data rect always finds at least itself
    assert (got >= 1).all()


# ---------------------------------------------------------------------------
# Generators
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dist", sorted(spider.DISTRIBUTIONS))
def test_spider_distributions_valid(dist):
    r = spider.generate(dist, 2000, seed=1)
    assert r.shape == (2000, 4) and r.dtype == np.int32
    assert (r[:, 0] <= r[:, 2]).all() and (r[:, 1] <= r[:, 3]).all()
    assert r.min() >= 0 and r.max() <= spider.SCALE
    # determinism
    r2 = spider.generate(dist, 2000, seed=1)
    assert (r == r2).all()


def test_query_workload_fractions():
    from repro.data import datasets
    rects = spider.uniform(10_000, seed=2)
    q = datasets.make_queries(rects, 0.05)
    assert q.shape == (500, 4)
    assert (q[:, 0] <= q[:, 2]).all()
    assert rect_overlap_np(q[:5, None, :], rects[None, :, :]).any()
