"""Training substrate: optimizer behaviour, fault tolerance (checkpoint /
restart / elastic re-shard), gradient compression, straggler monitor, and
pipeline parallelism (subprocess, multi-device)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from repro import compat
import jax.numpy as jnp

from repro import configs
from repro.train import checkpoint as ckpt
from repro.train import train_loop
from repro.train.optimizer import AdamW, cosine_schedule, global_norm

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _mesh1():
    return compat.make_mesh((1, 1), ("data", "model"))


def test_adamw_reduces_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, gnorm = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2
    assert np.isfinite(float(gnorm))


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 1e-6
    assert float(lr(100)) < 1e-6
    assert float(lr(55)) < float(lr(20))


def test_train_loss_decreases(tmp_path):
    cfg = configs.get_config("llama3.2-1b", smoke=True)
    res = train_loop.train(
        cfg, _mesh1(), steps=25, batch_size=4, seq_len=32,
        ckpt_dir=None, lr=3e-3, seed=3, log=lambda *_: None)
    first = np.mean(res["losses"][:5])
    last = np.mean(res["losses"][-5:])
    assert last < first - 0.1, (first, last)


def test_checkpoint_restart_bitexact(tmp_path):
    cfg = configs.get_config("qwen2-1.5b", smoke=True)
    mesh = _mesh1()
    kw = dict(batch_size=2, seq_len=16, ckpt_every=3, lr=1e-3, seed=7,
              log=lambda *_: None)
    # uninterrupted run to step 6
    full = train_loop.train(cfg, mesh, steps=6,
                            ckpt_dir=str(tmp_path / "a"), **kw)
    # interrupted: run to 3 (checkpoint), then "crash" and resume to 6
    train_loop.train(cfg, mesh, steps=3, ckpt_dir=str(tmp_path / "b"), **kw)
    resumed = train_loop.train(cfg, mesh, steps=6,
                               ckpt_dir=str(tmp_path / "b"), **kw)
    fa = jax.tree_util.tree_leaves(full["params"])
    fb = jax.tree_util.tree_leaves(resumed["params"])
    for a, b in zip(fa, fb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity(tmp_path):
    """A .tmp directory (simulated crash mid-save) is never picked up."""
    d = str(tmp_path)
    os.makedirs(os.path.join(d, "step_9.tmp"))
    assert ckpt.latest_step(d) is None
    cfg = configs.get_config("llama3.2-1b", smoke=True)
    from repro.models import api
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW().init(params)
    ckpt.save(d, 2, params, opt, {"step": 2})
    assert ckpt.latest_step(d) == 2


def test_straggler_monitor():
    mon = train_loop.StragglerMonitor(threshold=3.0)
    for i in range(20):
        assert not mon.record(i, 1.0)
    assert mon.record(20, 10.0)          # 10× median → flagged
    assert mon.flagged == [20]
    assert not mon.record(21, 1.1)


_MULTIDEV_TRAIN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from repro import configs
    from repro.models import api
    from repro.parallel import compress
    from repro.parallel.sharding import use_mesh, param_shardings
    from repro.train import train_loop, checkpoint as ckpt
    from repro.train.optimizer import AdamW
    from jax.sharding import PartitionSpec as P
    from repro import compat

    mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = configs.get_config("llama3.2-1b", smoke=True)

    # --- int8 EF compression: compressed cross-pod mean ~= true mean -------
    def body(x, e):
        out, e = compress.int8_psum_mean({"g": x}, "pod", {"g": e})
        return out["g"], e["g"]
    xs = jnp.asarray(np.random.default_rng(0).normal(0, 1, (2, 64)),
                     jnp.float32)
    f = jax.jit(compat.shard_map(body, mesh=mesh,
                                 in_specs=(P("pod"), P("pod")),
                                 out_specs=(P("pod"), P("pod")),
                                 axis_names={"pod"}, check_vma=False))
    got, err = f(xs, jnp.zeros_like(xs))
    want = jnp.broadcast_to(xs.mean(0, keepdims=True), xs.shape)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=0.05)  # ≈ 2 int8 quantisation steps
    assert float(jnp.abs(err).max()) > 0  # EF captured the residual
    print("COMPRESS_OK")

    # --- compressed train step runs and roughly matches auto ---------------
    opt = AdamW(lr=1e-3)
    step_a, p_shapes, _ = train_loop.make_train_step(cfg, mesh, opt,
                                                     donate=False)
    with use_mesh(mesh):
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        params = jax.device_put(params, param_shardings(p_shapes, mesh))
        opt_state = opt.init(params)
        batch = {"tokens": jnp.asarray(
            np.random.default_rng(1).integers(0, cfg.vocab, (8, 16)),
            jnp.int32)}
        pa, _, ma = step_a(params, opt_state, batch)
    if hasattr(jax, "shard_map"):
        # Partial-manual shard_map over 'pod' with auto 'data'/'model' hard-
        # crashes the SPMD partitioner of older jaxlib (Check failed:
        # sharding.IsManualSubgroup()) — only exercised on modern jax.
        step_c, _, _ = train_loop.make_train_step(
            cfg, mesh, opt, cross_pod="compressed", donate=False)
        with use_mesh(mesh):
            err = compress.zero_error_state(params)
            pc, _, err, mc = step_c(params, opt_state, err, batch)
        # auto mode uses the vocab-parallel xent, compressed mode (manual
        # 'pod') the chunked path — same math, different fp32 reduction
        # grouping over bf16 logits
        np.testing.assert_allclose(float(ma["loss"]), float(mc["loss"]),
                                   rtol=5e-3)
        la = jax.tree_util.tree_leaves(pa)
        lc = jax.tree_util.tree_leaves(pc)
        diffs = [float(jnp.abs(a - c).max()) for a, c in zip(la, lc)]
        assert max(diffs) < 5e-3, max(diffs)  # int8 quantisation tolerance
    print("COMPRESSED_STEP_OK")

    # --- elastic restore: 8-device checkpoint onto a 2-device mesh ---------
    import tempfile
    d = tempfile.mkdtemp()
    ckpt.save(d, 1, params, opt_state, {"step": 1, "arch": cfg.arch_id})
    mesh2 = compat.make_mesh((1, 2), ("data", "model"))
    p2, o2, meta = ckpt.restore(d, 1, mesh=mesh2, abstract_params=p_shapes)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("ELASTIC_OK")

    # --- pipeline parallelism over 'pod' == plain loss ----------------------
    if hasattr(jax, "shard_map"):
        # Needs axis_index inside a partial-manual region; old jaxlib lowers
        # it to a PartitionId instruction its SPMD partitioner rejects.
        from repro.parallel.pipeline import make_pp_loss_fn
        cfg_pp = configs.get_config("llama3.2-1b", smoke=True)
        pp_loss = make_pp_loss_fn(cfg_pp, mesh, num_microbatches=4)
        with use_mesh(mesh):
            plain = float(jax.jit(
                lambda p, b: api.loss_fn(cfg_pp, p, b))(params, batch))
            piped = float(jax.jit(pp_loss)(params, batch))
        np.testing.assert_allclose(piped, plain, rtol=2e-2)
        g = jax.jit(jax.grad(pp_loss))(params, batch)
        assert all(bool(jnp.isfinite(x).all())
                   for x in jax.tree_util.tree_leaves(g))
    print("PIPELINE_OK")
""")


@pytest.mark.slow
def test_multidevice_train_substrate():
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO_SRC
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _MULTIDEV_TRAIN],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    for tag in ("COMPRESS_OK", "COMPRESSED_STEP_OK", "ELASTIC_OK",
                "PIPELINE_OK"):
        assert tag in r.stdout
