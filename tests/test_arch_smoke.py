"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train-grad step + one decode step on CPU; asserts shapes and no NaNs.

The FULL assigned configs are exercised only via the dry-run
(ShapeDtypeStruct, no allocation) per the assignment rules.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import api
from repro.models.base import validate


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_full_config_is_assigned_shape(arch):
    cfg = configs.get_config(arch)
    validate(cfg)
    assigned = {
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256_000),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152_064),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256_000),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32_256),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128_256),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151_936),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49_155),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151_936),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51_865),
        "falcon-mamba-7b": (64, 4096, 1, 1, 0, 65_024),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == assigned


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_forward_and_grad(arch):
    cfg = configs.get_config(arch, smoke=True)
    validate(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init_params(cfg, key)
    batch = api.make_train_batch(cfg, batch=2, seq=32, seed=1)
    logits = api.forward(cfg, params, batch)
    assert logits.shape == (2, 32, cfg.vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())

    loss, grads = jax.value_and_grad(
        lambda p: api.loss_fn(cfg, p, batch))(params)
    assert np.isfinite(float(loss))
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g.astype(jnp.float32)).all()) for g in flat)
    # at least one grad leaf is nonzero
    assert any(float(jnp.abs(g.astype(jnp.float32)).max()) > 0 for g in flat)


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = configs.get_config(arch, smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.bfloat16)
    state = api.init_decode_state(cfg, batch_size=2, seq_len=64)
    for pos in (0, 1, 5):
        batch = api.make_decode_batch(cfg, batch=2, pos=pos, seed=pos)
        logits, state = api.decode_step(cfg, params, state, batch)
        assert logits.shape == (2, 1, cfg.vocab)
        assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", ["llama3.2-1b", "qwen2-1.5b",
                                  "falcon-mamba-7b", "recurrentgemma-2b",
                                  "granite-moe-3b-a800m"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce the full-sequence forward logits
    (the KV-cache / recurrent-state path is numerically consistent)."""
    cfg = configs.get_config(arch, smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(1), dtype=jnp.float32)
    rng = np.random.default_rng(2)
    seq = 12
    tokens = rng.integers(0, cfg.vocab, (1, seq)).astype(np.int32)
    batch = {"tokens": jnp.asarray(tokens)}
    if cfg.family == "vlm":
        pytest.skip("vlm forward needs patch inputs; covered elsewhere")
    full = np.asarray(api.forward(cfg, params, batch).astype(jnp.float32))

    state = api.init_decode_state(cfg, batch_size=1, seq_len=seq,
                                  dtype=jnp.float32)
    outs = []
    for pos in range(seq):
        db = {"tokens": jnp.asarray(tokens[:, pos: pos + 1]),
              "pos": jnp.asarray(pos, jnp.int32)}
        lg, state = api.decode_step(cfg, params, state, db)
        outs.append(np.asarray(lg.astype(jnp.float32))[:, 0])
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, full, rtol=2e-2, atol=2e-2)


def test_serve_decode_steady_state_guarded(pallint_steady_state):
    """The serving decode loop obeys the hot-path doctrine after warmup:
    no recompiles, no implicit device->host transfers (pallint GR301/302).
    The cache is placed on its steady shardings up front — the donated
    output comes back committed, so an uncommitted init state would cost a
    second specialization on the first steady step."""
    from repro import compat
    from repro.serve import serve_loop

    cfg = configs.get_config("llama3.2-1b", smoke=True)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    bs, seq = 2, 16
    step, _, st_shapes, _ = serve_loop.make_decode_step(
        cfg, mesh, bs, seq, dtype=jnp.float32)
    params = api.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    state = api.init_decode_state(cfg, bs, seq, dtype=jnp.float32)
    state = jax.device_put(
        state, serve_loop.state_shardings(cfg, mesh, st_shapes))
    rng = np.random.default_rng(9)

    def batch(pos):
        return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (bs, 1)),
                                      jnp.int32),
                "pos": jnp.asarray(pos, jnp.int32)}

    _, state = step(params, state, batch(0))           # warmup compile
    with pallint_steady_state(entrypoints={"decode_step": step},
                              where="serve_loop.decode_step"):
        for pos in range(1, 4):
            logits, state = step(params, state, batch(pos))
    assert logits.shape == (bs, 1, cfg.vocab)


def test_cells_and_skips():
    cells = configs.all_cells()
    # 10 archs × 4 shapes − 8 long_500k skips = 32 LM cells
    assert len(cells) == 32
    for arch in ("falcon-mamba-7b", "recurrentgemma-2b"):
        assert (arch, "long_500k") in cells
    for arch in ("minitron-8b", "qwen2-vl-72b", "whisper-medium"):
        assert (arch, "long_500k") not in cells


def test_param_counts_sane():
    n = configs.get_config("llama3.2-1b").param_count()
    assert 1.0e9 < n < 1.6e9
    n72 = configs.get_config("qwen2-vl-72b").param_count()
    assert 6.5e10 < n72 < 8.5e10
    moe = configs.get_config("qwen2-moe-a2.7b")
    assert moe.active_param_count() < 0.45 * moe.param_count()
