"""Chaos suite: under every injected fault the serving loop never crashes or
hangs — it retries, sheds, or degrades to the reference path, and every
completed response is exact.

All tests are marked ``chaos`` and run both in tier-1 and in the dedicated
CI chaos job (with ``timeout-minutes`` as the outer hang guard).  Fault
schedules are deterministic (:mod:`repro.testing.chaos`), so failures replay.
"""
import numpy as np
import pytest

from repro import compat
from repro.core import engine as beng
from repro.core import rtree
from repro.data import datasets, spider
from repro.kernels import ref
from repro.serve.spatial_serve import (
    DEGRADED, HEALTHY, ServeConfig, SpatialServer)
from repro.testing import chaos

pytestmark = pytest.mark.chaos


@pytest.fixture(scope="module")
def workload():
    rects = spider.uniform(2500, seed=61, max_size=0.02)
    queries = datasets.make_queries(rects, 0.2, seed=62)   # 500 queries
    tree = rtree.build_str_3level(rects, leaf_capacity=32, fanout=8)
    want = ref.overlap_counts_np(queries, rects)
    return rects, queries, tree, want


def _server(tree, **overrides):
    cfg = dict(batch_size=64, watchdog_s=30.0, max_retries=2,
               backoff_base_s=0.001, backoff_cap_s=0.01, probe_every=1)
    cfg.update(overrides)
    eng = beng.BroadcastEngine(tree, compat.make_mesh((1, 1),
                                                      ("data", "model")),
                               batch_size=64)
    return SpatialServer(eng, ServeConfig(**cfg))


def _serve_all(srv, queries):
    tickets = [srv.submit(q, deadline_s=120.0) for q in queries]
    srv.drain()
    assert all(t.done for t in tickets), "serving loop hung"
    return np.array([t.count for t in tickets], dtype=np.int32), tickets


def test_device_loss_transient_retries(workload):
    """A lost device for two calls: retried with backoff, exact output,
    still healthy at the end."""
    _, queries, tree, want = workload
    srv = _server(tree)
    inj = chaos.ChaosInjector(
        [chaos.Fault(chaos.DEVICE_LOSS, at_call=1, count=2)]).install(srv)
    got, _ = _serve_all(srv, queries)
    np.testing.assert_array_equal(got, want)
    m = srv.metrics()
    assert m["retries"] >= 2 and m["faults"].get("DeviceLostError") == 2
    assert m["health"] == HEALTHY and m["degradations"] == 0
    assert [k for _, k in inj.log] == ["device_loss", "device_loss"]


def test_device_loss_persistent_degrades_then_recovers(workload):
    """Retries exhausted → degrade to the reference kernel; the periodic
    probe recovers the fast path once the device returns.  Every response
    is exact on both paths."""
    _, queries, tree, want = workload
    srv = _server(tree, max_retries=0)
    chaos.ChaosInjector(
        [chaos.Fault(chaos.DEVICE_LOSS, at_call=0, count=2)]).install(srv)
    got, tickets = _serve_all(srv, queries)
    np.testing.assert_array_equal(got, want)
    m = srv.metrics()
    assert m["degradations"] == 1 and m["degraded_batches"] >= 1
    assert m["recoveries"] == 1 and m["health"] == HEALTHY
    paths = [t.path for t in tickets]
    assert "ref" in paths and "fast" in paths    # degraded, then recovered


def test_straggler_trips_watchdog(workload):
    """A shard stalling past the watchdog budget is abandoned and retried —
    tail latency bumps, correctness does not."""
    _, queries, tree, want = workload
    srv = _server(tree, watchdog_s=0.2, max_retries=2)
    chaos.ChaosInjector(
        [chaos.Fault(chaos.STRAGGLER, at_call=2, count=1, delay_s=1.0)]
    ).install(srv)
    got, _ = _serve_all(srv, queries)
    np.testing.assert_array_equal(got, want)
    m = srv.metrics()
    assert m["faults"].get("watchdog") == 1
    assert m["health"] == HEALTHY


def test_nan_counts_never_released(workload):
    """Corrupted (NaN) kernel output is caught by the output sanity check —
    no corrupt count ever reaches a response."""
    _, queries, tree, want = workload
    srv = _server(tree)
    chaos.ChaosInjector(
        [chaos.Fault(chaos.NAN_COUNTS, at_call=3, count=1)]).install(srv)
    got, _ = _serve_all(srv, queries)
    np.testing.assert_array_equal(got, want)
    assert srv.metrics()["faults"].get("corrupt") == 1


def test_corrupt_counts_never_released(workload):
    _, queries, tree, want = workload
    srv = _server(tree)
    chaos.ChaosInjector(
        [chaos.Fault(chaos.CORRUPT, at_call=1, count=1)]).install(srv)
    got, _ = _serve_all(srv, queries)
    np.testing.assert_array_equal(got, want)
    assert srv.metrics()["faults"].get("corrupt") == 1


def test_plausible_corruption_caught_by_crosscheck(workload):
    """Off-by-one corruption passes the bounds sanity check; the sampled
    oracle cross-check catches it (crosscheck_every=1 → every batch)."""
    _, queries, tree, want = workload
    srv = _server(tree, crosscheck_every=1, crosscheck_samples=64)
    calls = {"n": 0}
    real_step = srv._step

    def off_by_one_step(*args, **kw):
        idx = calls["n"]
        calls["n"] += 1
        out = np.asarray(real_step(*args, **kw))
        return out + 1 if idx == 2 else out

    srv._step = off_by_one_step
    got, _ = _serve_all(srv, queries)
    np.testing.assert_array_equal(got, want)
    m = srv.metrics()
    assert m["faults"].get("corrupt") == 1
    assert m["crosschecks"] >= 1


def test_placement_oom_retries(workload):
    """RESOURCE_EXHAUSTED during batch staging is retried like any other
    fast-path fault."""
    _, queries, tree, want = workload
    srv = _server(tree)
    chaos.ChaosInjector(
        [chaos.Fault(chaos.OOM, at_call=1, count=2)]).install(srv)
    got, _ = _serve_all(srv, queries)
    np.testing.assert_array_equal(got, want)
    assert srv.metrics()["faults"].get("PlacementOOMError") == 2


def test_total_fast_path_loss_still_serves_exactly(workload):
    """Worst case: the fast path never works at all.  The server degrades
    permanently to the reference kernel and still answers every request
    exactly — availability through graceful degradation, not a hang."""
    _, queries, tree, want = workload
    srv = _server(tree, max_retries=1, probe_every=4)
    chaos.ChaosInjector(
        [chaos.Fault(chaos.DEVICE_LOSS, at_call=0, count=10**6)]).install(srv)
    got, tickets = _serve_all(srv, queries)
    np.testing.assert_array_equal(got, want)
    m = srv.metrics()
    assert m["health"] == DEGRADED
    assert all(t.path == "ref" for t in tickets if t.status == "ok")


def test_fault_plan_validation():
    with pytest.raises(ValueError):
        chaos.Fault("nonsense", at_call=0)
    with pytest.raises(ValueError):
        chaos.Fault(chaos.OOM, at_call=-1)
    with pytest.raises(ValueError):
        chaos.Fault(chaos.OOM, at_call=0, count=0)
    with pytest.raises(ValueError):
        chaos.Fault(chaos.OOM, at_call=0, count=3, period=2)  # period < count


def test_flapping_fault_schedule():
    """period turns a fault into a repeating window: count calls out of
    every period fire, deterministically by call index."""
    f = chaos.Fault(chaos.DEVICE_LOSS, at_call=2, count=1, period=3)
    fired = [i for i in range(11) if f.active(i)]
    assert fired == [2, 5, 8]
    assert not f.active(0) and not f.active(1)
    one_shot = chaos.Fault(chaos.DEVICE_LOSS, at_call=2, count=2)
    assert [i for i in range(8) if one_shot.active(i)] == [2, 3]


def test_random_plan_replays_from_seed():
    """Satellite: the seed is the whole state — same seed, same plan,
    always; different seeds differ; every plan validates."""
    a = chaos.random_plan(1234, n_faults=5)
    b = chaos.random_plan(1234, n_faults=5)
    assert a == b
    assert chaos.random_plan(1235, n_faults=5) != a
    for f in a:
        assert f.kind in chaos.KINDS and f.at_call >= 0 and f.count >= 1


def test_describe_carries_seed_and_fired_log(workload):
    """Failure output names the seed, the plan, and what actually fired —
    a chaos failure is replayable straight from the pytest report."""
    _, queries, tree, want = workload
    seed = 4242
    inj = chaos.ChaosInjector(
        [chaos.Fault(chaos.DEVICE_LOSS, at_call=0, count=1)], seed=seed)
    srv = _server(tree)
    inj.install(srv)
    got, _ = _serve_all(srv, queries[:64])
    np.testing.assert_array_equal(got, want[:64], err_msg=inj.describe())
    desc = inj.describe()
    assert f"seed={seed}" in desc
    assert "device_loss@0x1" in desc
    assert "(0, 'device_loss')" in desc
    assert repr(inj) == desc


def test_seeded_plan_through_server_is_exact(workload):
    """A seed-derived plan drives the server exactly like a hand-written
    one; assertions carry describe() so failures replay from the seed."""
    _, queries, tree, want = workload
    seed = 99
    plan = chaos.random_plan(seed, n_faults=3, max_call=4, max_delay_s=0.05)
    inj = chaos.ChaosInjector(plan, seed=seed)
    srv = _server(tree, max_retries=3)
    inj.install(srv)
    got, tickets = _serve_all(srv, queries)
    np.testing.assert_array_equal(got, want, err_msg=inj.describe())
    assert all(t.done for t in tickets), inj.describe()


def test_chaos_wrappers_compose_at_bare_seams(workload):
    """wrap_step also works at the offline ``stream_batches`` seam — the
    wrapped step is a drop-in for the jitted step callable."""
    rects, queries, tree, want = workload
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    eng = beng.BroadcastEngine(tree, mesh, batch_size=64)
    inj = chaos.ChaosInjector([chaos.Fault(chaos.STRAGGLER, at_call=0,
                                           count=1, delay_s=0.0)])
    wrapped = inj.wrap_step(eng._step)
    got = beng.stream_batches(
        wrapped, (eng.leaf_coords, eng.rect_tile_mbrs, eng.cover_mbrs),
        queries[:64], 64, eng._rep_sh)
    np.testing.assert_array_equal(got, want[:64])
    assert inj.step_calls == 1 and inj.log == [(0, "straggler")]
