"""Property test: the host and device active-tile builders agree.

``ops.build_active_tiles`` (NumPy, placement time) and
``ops.build_active_tiles_device`` (jnp, trace-safe serving path) must
produce identical active sets — same per-query-tile counts and the same
rect-tile IDs in the same (ascending) order — on any layout, including
EMPTY-padded tails and adversarially sparse overlap structure.  The two
builders differ only in list width: the host packs to the observed max,
the device keeps the static worst case; entries past ``nactive`` are dead
on both sides and excluded from the comparison.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops

EMPTY = np.array([2**31 - 1, 2**31 - 1, -(2**31), -(2**31)], np.int32)


def _rand_mbrs(n, rng, scale, span):
    lo = rng.integers(0, scale, (n, 2))
    hi = lo + rng.integers(0, span + 1, (n, 2))
    return np.concatenate([lo, hi], axis=1).astype(np.int32)


def _assert_equivalent(qmbrs, rmbrs):
    h_n, h_ids = ops.build_active_tiles(qmbrs, rmbrs)
    d_n, d_ids = ops.build_active_tiles_device(
        jnp.asarray(qmbrs), jnp.asarray(rmbrs))
    d_n = np.asarray(d_n)
    d_ids = np.asarray(d_ids)
    np.testing.assert_array_equal(h_n, d_n)
    for i, n in enumerate(h_n):
        np.testing.assert_array_equal(h_ids[i, :n], d_ids[i, :n])


@settings(max_examples=30, deadline=None)
@given(nq=st.integers(1, 12), nr=st.integers(1, 16),
       seed=st.integers(0, 2**16), span=st.integers(0, 400))
def test_active_tiles_host_device_equivalent(nq, nr, seed, span):
    """Random tile layouts, from dense (huge spans) to nearly disjoint."""
    rng = np.random.default_rng(seed)
    _assert_equivalent(_rand_mbrs(nq, rng, 1000, span),
                       _rand_mbrs(nr, rng, 1000, span))


@settings(max_examples=30, deadline=None)
@given(nq=st.integers(1, 10), nr=st.integers(2, 16),
       seed=st.integers(0, 2**16), nempty=st.integers(1, 8))
def test_active_tiles_with_empty_padding(nq, nr, seed, nempty):
    """EMPTY (lo > hi) rect-tile MBRs — the padded tail of a placed layout —
    never enter either builder's active set."""
    rng = np.random.default_rng(seed)
    rmbrs = _rand_mbrs(nr, rng, 1000, 200)
    k = min(nempty, nr - 1)
    rmbrs[nr - k:] = EMPTY
    _assert_equivalent(_rand_mbrs(nq, rng, 1000, 200), rmbrs)


def test_active_tiles_adversarially_sparse():
    """One distant rect tile per query tile (a diagonal active matrix) plus
    boundary-touching tiles: the stable-argsort packing must keep ascending
    tile order on both sides."""
    n = 8
    qmbrs = np.stack([np.arange(n) * 10_000,
                      np.zeros(n, np.int64),
                      np.arange(n) * 10_000 + 10,
                      np.full(n, 10)], axis=1).astype(np.int32)
    # reversed: query tile i overlaps only rect tile n-1-i
    rmbrs = qmbrs[::-1].copy()
    _assert_equivalent(qmbrs, rmbrs)
    # closed-interval touch: rect tile shares exactly one edge coordinate
    touch = qmbrs.copy()
    touch[:, 0] = touch[:, 2]                       # degenerate vertical line
    _assert_equivalent(qmbrs, touch)


def test_active_tiles_all_dead():
    """No overlaps at all: nactive is all-zero and every slot is the masked
    tile-0 placeholder on both sides."""
    qmbrs = np.array([[0, 0, 10, 10], [20, 20, 30, 30]], np.int32)
    rmbrs = np.array([[1000, 1000, 1010, 1010]], np.int32)
    h_n, h_ids = ops.build_active_tiles(qmbrs, rmbrs)
    d_n, d_ids = ops.build_active_tiles_device(
        jnp.asarray(qmbrs), jnp.asarray(rmbrs))
    assert h_n.tolist() == [0, 0] and np.asarray(d_n).tolist() == [0, 0]
    assert (h_ids == 0).all() and (np.asarray(d_ids) == 0).all()


def test_active_tiles_device_cover_filter():
    """The device builder's cover filter empties exactly the query tiles
    that miss every L1 cover MBR."""
    qmbrs = np.array([[0, 0, 10, 10], [500, 500, 510, 510]], np.int32)
    rmbrs = np.array([[0, 0, 1000, 1000]], np.int32)
    covers = np.array([[0, 0, 50, 50]], np.int32)   # hits tile 0 only
    d_n, _ = ops.build_active_tiles_device(
        jnp.asarray(qmbrs), jnp.asarray(rmbrs), jnp.asarray(covers))
    assert np.asarray(d_n).tolist() == [1, 0]
