"""Fault-tolerant serving loop: steady-state exactness, admission control,
deadlines, metrics, and the reference-kernel twin.

The chaos (fault-injection) suite lives in ``tests/test_chaos.py``; this
file covers the no-fault contract: in healthy steady state the server's
outputs are bit-equal to ``BroadcastEngine.query``, requests are shed/expired
explicitly, and the health/metrics surface reports what happened.
"""
import numpy as np
import pytest

from repro import compat
from repro.core import engine as beng
from repro.core import rtree, subtree
from repro.core.engine import QueryValidationError
from repro.data import datasets, spider
from repro.kernels import ref
from repro.serve.spatial_serve import (
    DEGRADED, HEALTHY, STATUS_CANCELLED, STATUS_EXPIRED, STATUS_OK,
    STATUS_SHED, ServeConfig, SpatialServer)


def _mesh1():
    return compat.make_mesh((1, 1), ("data", "model"))


class FakeClock:
    """Deterministic clock + sleep for deadline tests."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def sleep(self, s):
        self.t += s


@pytest.fixture(scope="module")
def workload():
    rects = spider.uniform(3000, seed=51, max_size=0.02)
    queries = datasets.make_queries(rects, 0.2, seed=52)   # 600 queries
    tree = rtree.build_str_3level(rects, leaf_capacity=32, fanout=8)
    return rects, queries, tree


@pytest.fixture()
def engine(workload):
    _, _, tree = workload
    return beng.BroadcastEngine(tree, _mesh1(), batch_size=64)


def test_steady_state_bit_equal_to_engine(workload, engine):
    """Acceptance: no-fault steady state is bit-equal to the offline path."""
    rects, queries, _ = workload
    srv = SpatialServer(engine, ServeConfig(batch_size=64, watchdog_s=30.0))
    tickets = [srv.submit(q, deadline_s=60.0) for q in queries]
    srv.drain()
    got = np.array([t.count for t in tickets], dtype=np.int32)
    np.testing.assert_array_equal(got, engine.query(queries))
    np.testing.assert_array_equal(got, ref.overlap_counts_np(queries, rects))
    assert all(t.status == STATUS_OK and t.path == "fast" for t in tickets)
    m = srv.metrics()
    assert m["health"] == HEALTHY
    assert m["served"] == len(queries) and m["shed"] == 0
    assert m["retries"] == 0 and m["degradations"] == 0


def test_serves_subtree_engine_too(workload):
    """The server binds to either engine — same step arity, same contract."""
    rects, queries, _ = workload
    eng = subtree.SubtreeEngine(rects, _mesh1(), leaf_capacity=64,
                                batch_size=64)
    srv = SpatialServer(eng, ServeConfig(batch_size=64, watchdog_s=30.0,
                                         sort_batches=False))
    tickets = [srv.submit(q, deadline_s=60.0) for q in queries[:100]]
    srv.drain()
    got = np.array([t.count for t in tickets], dtype=np.int32)
    np.testing.assert_array_equal(
        got, ref.overlap_counts_np(queries[:100], rects))


def test_capacity_shedding(engine):
    srv = SpatialServer(engine, ServeConfig(batch_size=64, max_queue=4),
                        warmup=False)
    rect = np.array([0, 0, 10, 10], np.int32)
    tickets = [srv.submit(rect) for _ in range(7)]
    shed = [t for t in tickets if t.status == STATUS_SHED]
    assert len(shed) == 3 and all(t.reason == "capacity" for t in shed)
    assert all(t.done for t in shed)       # shed tickets complete immediately
    m = srv.metrics()
    assert m["shed"] == 3 and 0 < m["shed_rate"] < 1
    assert m["queue_depth"] == 4


def test_deadline_admission_shed(engine):
    """With a known batch-latency EWMA, a request whose deadline cannot be
    met is refused at admission instead of queued to die."""
    clk = FakeClock()
    srv = SpatialServer(engine, ServeConfig(batch_size=64),
                        clock=clk, sleep=clk.sleep, warmup=False)
    srv._batch_ewma_s = 10.0               # measured-latency stand-in
    rect = np.array([0, 0, 10, 10], np.int32)
    t_ok = srv.submit(rect, deadline_s=100.0)
    t_no = srv.submit(rect, deadline_s=0.5)
    assert t_ok.status != STATUS_SHED
    assert t_no.status == STATUS_SHED and t_no.reason == "deadline"


def test_zero_and_negative_deadline_shed_at_submit(engine):
    """Satellite: an already-expired deadline is shed at submit — it never
    occupies a batch slot waiting to be noticed at batch formation."""
    srv = SpatialServer(engine, ServeConfig(batch_size=64), warmup=False)
    rect = np.array([0, 0, 10, 10], np.int32)
    for d in (0.0, -1.0):
        t = srv.submit(rect, deadline_s=d)
        assert t.done and t.status == STATUS_SHED
        assert t.reason == "deadline"
    assert srv.queue_depth == 0              # no batch slot consumed
    m = srv.metrics()
    assert m["shed"] == 2 and m["counters"]["shed_deadline"] == 2
    assert m["submitted"] == 2


def test_cancel_withdraws_queued_request(engine):
    """A queued request can be withdrawn (hedging's loser path); a request
    already completed cannot."""
    srv = SpatialServer(engine, ServeConfig(batch_size=64), warmup=False)
    rect = np.array([0, 0, 10, 10], np.int32)
    t1 = srv.submit(rect, deadline_s=100.0)
    t2 = srv.submit(rect, deadline_s=100.0)
    assert srv.queue_depth == 2
    assert srv.cancel(t1, reason="hedge_lost")
    assert t1.done and t1.status == STATUS_CANCELLED
    assert t1.reason == "hedge_lost" and t1.count is None
    assert srv.queue_depth == 1
    assert not srv.cancel(t1)                # already out of the queue
    srv.pump()
    assert t2.status == STATUS_OK
    assert not srv.cancel(t2)                # already served
    m = srv.metrics()
    assert m["counters"]["cancelled"] == 1 and m["served"] == 1


def test_expired_in_queue(engine):
    """Requests whose deadline passes while queued are expired at batch
    formation, never silently served late."""
    clk = FakeClock()
    srv = SpatialServer(engine, ServeConfig(batch_size=64),
                        clock=clk, sleep=clk.sleep)
    rect = np.array([0, 0, 10, 10], np.int32)
    t1 = srv.submit(rect, deadline_s=0.5)
    t2 = srv.submit(rect, deadline_s=100.0)
    clk.t += 1.0
    srv.pump()
    assert t1.status == STATUS_EXPIRED and t1.done
    assert t2.status == STATUS_OK
    assert srv.metrics()["expired"] == 1


def test_submit_validates_strictly(engine):
    srv = SpatialServer(engine, warmup=False)
    with pytest.raises(QueryValidationError):
        srv.submit(np.array([10, 10, 0, 0], np.int32))     # lo > hi: refused
    with pytest.raises(QueryValidationError):
        srv.submit(np.array([np.nan, 0.0, 1.0, 1.0]))
    with pytest.raises(QueryValidationError):
        srv.submit(np.array([1, 2, 3], np.int32))          # wrong shape


def test_background_worker_thread(workload, engine):
    rects, queries, _ = workload
    srv = SpatialServer(engine, ServeConfig(batch_size=64, watchdog_s=30.0))
    srv.start()
    try:
        tickets = [srv.submit(q, deadline_s=60.0) for q in queries[:200]]
        assert all(t.wait(timeout=60.0) for t in tickets)
    finally:
        srv.stop()
    got = np.array([t.count for t in tickets], dtype=np.int32)
    np.testing.assert_array_equal(
        got, ref.overlap_counts_np(queries[:200], rects))
    assert srv.submit(np.array([0, 0, 1, 1])).status == STATUS_SHED  # stopped


def test_metrics_latency_percentiles(workload, engine):
    _, queries, _ = workload
    srv = SpatialServer(engine, ServeConfig(batch_size=64, watchdog_s=30.0))
    for q in queries[:128]:
        srv.submit(q, deadline_s=60.0)
    srv.drain()
    m = srv.metrics()
    assert m["batch_p50_s"] is not None and m["batch_p99_s"] is not None
    assert m["batch_p50_s"] <= m["batch_p90_s"] <= m["batch_p99_s"]
    assert m["request_p50_s"] is not None
    assert m["request_p50_s"] <= m["request_p90_s"] <= m["request_p99_s"]
    assert m["queue_wait_p50_s"] is not None
    assert m["queue_wait_p50_s"] <= m["queue_wait_p99_s"]


def test_metrics_registry_backed_surface(workload, engine):
    """metrics() is a read-through over the shared registry: the counters
    dict keeps its historical int shape, and the same numbers appear in the
    Prometheus exposition."""
    _, queries, _ = workload
    srv = SpatialServer(engine, ServeConfig(batch_size=64, watchdog_s=30.0))
    for q in queries[:96]:
        srv.submit(q, deadline_s=60.0)
    srv.drain()
    m = srv.metrics()
    assert isinstance(m["served"], int) and isinstance(m["shed"], int)
    assert m["served"] == 96 and m["submitted"] == 96
    text = srv.registry.prometheus_text()
    assert 'serve_events_total{kind="served"} 96' in text
    assert "serve_batch_latency_seconds_bucket" in text
    assert "serve_request_latency_seconds_count 96" in text
    assert "serve_healthy 1" in text
    # an externally supplied registry is used as-is (shared scrape surface)
    from repro.obs import metrics as obs_metrics
    mine = obs_metrics.Registry()
    srv2 = SpatialServer(engine, ServeConfig(batch_size=64, watchdog_s=30.0),
                         registry=mine, warmup=False)
    assert srv2.registry is mine
    assert "serve_healthy" in mine.prometheus_text()


def test_ref_chunked_twin_matches_loop_oracle():
    """The degraded path's vectorized kernel is exact vs the per-query
    oracle, across chunk boundaries and EMPTY padding."""
    rects = spider.gaussian(700, seed=53, max_size=0.02)
    queries = datasets.make_queries(rects, 0.5, seed=54)   # 350 queries
    want = ref.overlap_counts_np(queries, rects)
    for chunk in (1, 7, 256, 1000):
        got = ref.overlap_counts_np_chunked(queries, rects, chunk=chunk)
        np.testing.assert_array_equal(got, want)
    assert got.dtype == np.int32


def test_degraded_state_constant(engine):
    """HEALTHY/DEGRADED markers round-trip through the metrics surface."""
    srv = SpatialServer(engine, warmup=False)
    assert srv.metrics()["health"] == HEALTHY
    srv._degrade(RuntimeError("forced"))
    assert srv.metrics()["health"] == DEGRADED
    assert srv.metrics()["degradations"] == 1
