"""Unit tests for the perf-regression gate in benchmarks/regress.py —
pure comparison logic, no benchmark execution."""
import json

import pytest

from benchmarks import regress


def _report(serving=22.7, bulk=0.85, build=3.8):
    return {
        "pipeline": [
            {"bench": "pipeline_serving", "speedup": serving},
            {"bench": "pipeline_bulk", "speedup": bulk},
        ],
        "build": {"speedup": build},
    }


def test_gate_passes_at_baseline():
    base = _report()
    assert regress._regression_failures(_report(), base) == []


def test_gate_passes_within_tolerance():
    base = _report(serving=20.0)
    ok = _report(serving=20.0 * 0.81)      # -19%: inside the 20% band
    assert regress._regression_failures(ok, base) == []


def test_gate_fails_on_pipeline_drop():
    base = _report(serving=20.0)
    bad = _report(serving=20.0 * 0.79)     # -21%: outside the band
    fails = regress._regression_failures(bad, base)
    assert len(fails) == 1 and "pipeline_serving" in fails[0]


def test_gate_fails_on_build_drop():
    base = _report(build=4.0)
    fails = regress._regression_failures(_report(build=2.0), base)
    assert len(fails) == 1 and fails[0].startswith("build:")


def test_gate_reports_every_failing_row():
    base = _report(serving=20.0, bulk=1.0, build=4.0)
    bad = _report(serving=10.0, bulk=0.4, build=1.0)
    assert len(regress._regression_failures(bad, base)) == 3


def test_gate_disabled_without_baseline():
    assert regress._regression_failures(_report(serving=0.01), None) == []


def test_gate_ignores_unknown_rows():
    base = {"pipeline": [{"bench": "pipeline_serving", "speedup": 20.0}]}
    new = {"pipeline": [{"bench": "pipeline_other", "speedup": 0.1}]}
    assert regress._regression_failures(new, base) == []


def test_load_baseline_roundtrip(tmp_path, monkeypatch):
    path = tmp_path / "BENCH_pipeline.json"
    monkeypatch.setattr(regress, "OUT_PATH", str(path))
    assert regress._load_baseline() is None          # missing file: no gate
    path.write_text("not json{")
    assert regress._load_baseline() is None          # unreadable: no gate
    path.write_text(json.dumps(_report()))
    assert regress._load_baseline() == _report()


def test_gate_and_record_exits_nonzero_and_keeps_baseline(
        monkeypatch, tmp_path):
    """A regressing run exits non-zero and must NOT overwrite the committed
    baseline (no downward ratchet)."""
    path = tmp_path / "BENCH_pipeline.json"
    committed = _report(serving=100.0)
    path.write_text(json.dumps(committed))
    monkeypatch.setattr(regress, "OUT_PATH", str(path))
    with pytest.raises(SystemExit) as exc:
        regress._gate_and_record(_report(serving=1.0))
    assert "NOT overwritten" in str(exc.value)
    assert json.loads(path.read_text()) == committed


def test_gate_and_record_overwrites_on_pass(monkeypatch, tmp_path):
    path = tmp_path / "BENCH_pipeline.json"
    path.write_text(json.dumps(_report(serving=20.0)))
    monkeypatch.setattr(regress, "OUT_PATH", str(path))
    improved = _report(serving=25.0)
    regress._gate_and_record(improved)
    assert json.loads(path.read_text()) == improved


# --- query-surface throughput gate (BENCH_query.json) ----------------------

from benchmarks import query_surface  # noqa: E402


def _q_report(ids=400.0, knn=220.0, radius=380.0, aggregate=10_000.0):
    return {"kinds": [
        {"kind": "ids", "qps": ids},
        {"kind": "knn", "qps": knn},
        {"kind": "radius", "qps": radius},
        {"kind": "aggregate", "qps": aggregate},
    ]}


def test_query_gate_passes_within_tolerance():
    base = _q_report(knn=200.0)
    ok = _q_report(knn=200.0 * 0.70)       # -30%: inside the 35% band
    assert query_surface.regression_failures(ok, base) == []


def test_query_gate_fails_on_throughput_drop():
    base = _q_report(knn=200.0)
    bad = _q_report(knn=200.0 * 0.60)      # -40%: outside the band
    fails = query_surface.regression_failures(bad, base)
    assert len(fails) == 1 and "query_knn" in fails[0]


def test_query_gate_reports_every_failing_kind():
    base = _q_report()
    bad = _q_report(ids=1.0, knn=1.0, radius=1.0, aggregate=1.0)
    assert len(query_surface.regression_failures(bad, base)) == 4


def test_query_gate_disabled_without_baseline():
    assert query_surface.regression_failures(_q_report(ids=0.01), None) == []


def test_query_gate_and_record_keeps_baseline_on_fail(monkeypatch, tmp_path):
    path = tmp_path / "BENCH_query.json"
    committed = _q_report(ids=1000.0)
    path.write_text(json.dumps(committed))
    monkeypatch.setattr(query_surface, "OUT_PATH", str(path))
    with pytest.raises(SystemExit) as exc:
        query_surface.gate_and_record(_q_report(ids=10.0))
    assert "NOT overwritten" in str(exc.value)
    assert json.loads(path.read_text()) == committed
    improved = _q_report(ids=2000.0)
    query_surface.gate_and_record(improved)
    assert json.loads(path.read_text()) == improved


def test_committed_query_baseline_has_all_kinds():
    """The repo-root BENCH_query.json must cover every query kind with
    throughput and overflow accounting where the kind can overflow."""
    base = query_surface.load_baseline()
    assert base is not None, "BENCH_query.json missing at repo root"
    rows = {r["kind"]: r for r in base["kinds"]}
    assert set(rows) == {"ids", "knn", "radius", "aggregate"}
    assert all(r["qps"] > 0 for r in rows.values())
    for kind in ("ids", "radius"):
        assert {"overflow_queries", "overflow_rate",
                "overflow_ids_total"} <= set(rows[kind])
