"""End-to-end query-surface tests: repro.query through both engines and the
serving stack.

All four query kinds (ids / knn / radius / aggregate) must be NumPy-oracle
exact — bit-equal IDs, counts, overflow, distances, and bboxes; aggregate
sums within the documented float tolerance — on the Broadcast AND Subtree
engines, through the offline ``stream_batches`` path (``query_*`` methods)
AND through ``SpatialServer`` per-kind micro-batching and the router.
Multi-device SPMD variants run in a subprocess with 8 fake host devices.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from repro import compat
from repro.core import rtree
from repro.core.engine import BroadcastEngine, QueryValidationError
from repro.core.subtree import SubtreeEngine
from repro.data import spider
from repro.kernels import ref
from repro.query import oracle

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

N_RECTS = 3000
Q = 220
KCAP = 16
K = 5


def _mesh1():
    return compat.make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="module")
def workload():
    rects = spider.uniform(N_RECTS, seed=3)
    rng = np.random.default_rng(7)
    queries = spider.uniform(Q, seed=11, max_size=0.02)
    points = rng.integers(0, spider.SCALE, (Q, 2)).astype(np.int32)
    radii = rng.integers(0, 60_000, Q).astype(np.int32)
    tree = rtree.build_str_3level(rects, leaf_capacity=32, fanout=8)
    return rects, queries, points, radii, tree


def _engine(kind, workload, **kw):
    rects, _, _, _, tree = workload
    mesh = _mesh1()
    if kind == "broadcast":
        return BroadcastEngine(tree, mesh, batch_size=64, **kw)
    return SubtreeEngine(rects, mesh, leaf_capacity=64, batch_size=64, **kw)


def _check_all_kinds(eng, workload):
    rects, queries, points, radii, _ = workload
    pr, pi = eng.placed_rects, eng.placed_ids
    # placement sanity: placed IDs are a permutation with matching coords
    live = pi >= 0
    assert np.array_equal(np.sort(pi[live]), np.arange(rects.shape[0]))
    assert np.array_equal(pr[live][np.argsort(pi[live])], rects)

    res = eng.query_ids(queries, kcap=KCAP)
    w_ids, w_cnt, w_ov = oracle.ids_oracle(queries, pr, pi, kcap=KCAP)
    np.testing.assert_array_equal(res.count, w_cnt)
    np.testing.assert_array_equal(res.ids, w_ids)
    np.testing.assert_array_equal(res.overflow, w_ov)
    assert res.total_overflow == int(w_ov.sum())

    res = eng.query_knn(points, k=K)
    w_d, w_i = oracle.knn_oracle(points, pr, pi, k=K)
    np.testing.assert_array_equal(res.ids, w_i)
    np.testing.assert_array_equal(res.distances, w_d)

    res = eng.query_radius(points, radii, kcap=KCAP)
    w_ids, w_cnt, w_ov = oracle.radius_oracle(points, radii, pr, pi,
                                              kcap=KCAP)
    np.testing.assert_array_equal(res.count, w_cnt)
    np.testing.assert_array_equal(res.ids, w_ids)
    np.testing.assert_array_equal(res.overflow, w_ov)

    res = eng.query_aggregate(queries)
    w_cnt, w_sums, w_bbox = oracle.aggregate_oracle(queries, pr)
    np.testing.assert_array_equal(res.count, w_cnt)
    np.testing.assert_array_equal(res.bbox, w_bbox)
    np.testing.assert_allclose(res.aggregates["sums"], w_sums,
                               rtol=oracle.AGG_RTOL, atol=oracle.AGG_ATOL)
    # centroid: NaN on zero-hit queries, Σ(lo+hi)/2n elsewhere
    cen = res.centroid
    zero = w_cnt == 0
    assert np.all(np.isnan(cen[zero]))
    np.testing.assert_allclose(
        cen[~zero], w_sums[~zero, :2] / (2.0 * w_cnt[~zero, None]), rtol=1e-5)


@pytest.mark.parametrize("engine_kind", ["broadcast", "subtree"])
def test_all_kinds_oracle_exact(engine_kind, workload):
    _check_all_kinds(_engine(engine_kind, workload, impl="xla"), workload)


@pytest.mark.parametrize("engine_kind", ["broadcast", "subtree"])
def test_all_kinds_oracle_exact_pallas(engine_kind, workload):
    _check_all_kinds(_engine(engine_kind, workload, impl="pallas"), workload)


def test_overflow_saturates_at_kcap(workload):
    """A kcap far below the densest query's count: the slot buffer holds the
    first kcap placed IDs and the remainder is accounted, never dropped
    silently."""
    _, queries, _, _, _ = workload
    eng = _engine("broadcast", workload, impl="xla")
    res = eng.query_ids(queries, kcap=2)
    w_ids, w_cnt, w_ov = oracle.ids_oracle(
        queries, eng.placed_rects, eng.placed_ids, kcap=2)
    np.testing.assert_array_equal(res.ids, w_ids)
    np.testing.assert_array_equal(res.overflow, w_ov)
    assert (res.count > 2).any()                 # the cap actually bites
    np.testing.assert_array_equal(res.overflow,
                                  np.maximum(res.count - 2, 0))


# ----------------------------------------------------------- validation edge

def test_engine_rejects_bad_points(workload):
    eng = _engine("broadcast", workload)
    with pytest.raises(QueryValidationError):
        eng.query_knn(np.zeros((3, 4), np.int32), k=3)      # rects, not points
    with pytest.raises(QueryValidationError):
        eng.query_knn(np.array([[0.5, 1.5]]), k=3)          # fractional
    with pytest.raises(QueryValidationError):
        eng.query_knn(np.array([[np.nan, 0.0]]), k=3)       # NaN coordinate


def test_engine_rejects_bad_k_and_radii(workload):
    eng = _engine("broadcast", workload)
    pts = np.array([[10, 10]], np.int32)
    for k in (0, -1, 2.5, "many"):
        with pytest.raises(QueryValidationError):
            eng.query_knn(pts, k=k)
    with pytest.raises(QueryValidationError):
        eng.query_radius(pts, np.array([np.nan]))
    with pytest.raises(QueryValidationError):
        eng.query_radius(pts, np.array([-3], np.int32))
    with pytest.raises(QueryValidationError):
        eng.query_radius(pts, np.array([1, 2], np.int32))   # length mismatch
    with pytest.raises(QueryValidationError):
        eng.query_ids(np.zeros((1, 4), np.int32), kcap=0)


def test_empty_batches_all_kinds(workload):
    eng = _engine("broadcast", workload)
    res = eng.query_ids(np.zeros((0, 4), np.int32), kcap=4)
    assert res.ids.shape == (0, 4) and res.count.shape == (0,)
    res = eng.query_knn(np.zeros((0, 2), np.int32), k=3)
    assert res.ids.shape == (0, 3) and res.distances.shape == (0, 3)
    res = eng.query_radius(np.zeros((0, 2), np.int32),
                           np.zeros((0,), np.int32), kcap=4)
    assert res.ids.shape == (0, 4)
    res = eng.query_aggregate(np.zeros((0, 4), np.int32))
    assert res.count.shape == (0,) and res.aggregates["sums"].shape == (0, 3)


# ------------------------------------------------------------------- serving

def _serve_pair(workload, **cfg_kw):
    from repro.serve.spatial_serve import ServeConfig, SpatialServer

    eng = _engine("broadcast", workload, impl="xla")
    cfg = ServeConfig(batch_size=16, kcap=KCAP, knn_k=K, **cfg_kw)
    return eng, SpatialServer(eng, cfg)


def test_server_mixed_kind_micro_batching(workload):
    """All five kinds interleaved through one server: per-kind batches form
    FIFO, every ticket comes back fast-path and oracle-exact."""
    _, queries, points, radii, _ = workload
    eng, srv = _serve_pair(workload, crosscheck_every=1,
                           crosscheck_samples=4)
    pr, pi = eng.placed_rects, eng.placed_ids
    n = 24
    tickets = []
    try:
        for i in range(n):
            tickets.append(("count", srv.submit(queries[i], deadline_s=30)))
            tickets.append(("ids", srv.submit(
                queries[i], kind="ids", deadline_s=30)))
            tickets.append(("knn", srv.submit(
                points[i], kind="knn", deadline_s=30)))
            tickets.append(("radius", srv.submit(
                points[i], kind="radius", radius=int(radii[i]),
                deadline_s=30)))
            tickets.append(("aggregate", srv.submit(
                queries[i], kind="aggregate", deadline_s=30)))
        assert srv.drain(timeout=120)
    finally:
        srv.stop()

    w_counts = ref.overlap_counts_np_chunked(queries[:n], srv._host_rects)
    w_ids, w_icnt, w_ov = oracle.ids_oracle(queries[:n], pr, pi, kcap=KCAP)
    w_d, w_ki = oracle.knn_oracle(points[:n], pr, pi, k=K)
    w_rids, w_rcnt, w_rov = oracle.radius_oracle(
        points[:n], radii[:n], pr, pi, kcap=KCAP)
    w_acnt, w_sums, w_bbox = oracle.aggregate_oracle(queries[:n], pr)

    idx = {k: 0 for k in ("count", "ids", "knn", "radius", "aggregate")}
    for kind, t in tickets:
        i = idx[kind]
        idx[kind] += 1
        assert t.status == "ok", (kind, i, t.status, t.reason)
        assert t.path == "fast", (kind, t.path)
        if kind == "count":
            assert t.count == int(w_counts[i])
        elif kind == "ids":
            assert t.count == int(w_icnt[i])
            assert np.array_equal(t.ids, w_ids[i])
            assert t.overflow == int(w_ov[i])
        elif kind == "knn":
            assert np.array_equal(t.ids, w_ki[i])
            assert np.array_equal(t.distances, w_d[i])
        elif kind == "radius":
            assert t.count == int(w_rcnt[i])
            assert np.array_equal(t.ids, w_rids[i])
            assert t.overflow == int(w_rov[i])
        else:
            assert t.count == int(w_acnt[i])
            assert np.array_equal(t.aggregates["bbox"], w_bbox[i])
            np.testing.assert_allclose(
                t.aggregates["sums"], w_sums[i],
                rtol=oracle.AGG_RTOL, atol=oracle.AGG_ATOL)
    m = srv.metrics()
    assert m["queries_by_kind"] == {k: n for k in idx}
    assert m["health"] == "healthy"


def test_server_rejects_malformed_at_submit(workload):
    _, queries, points, _, _ = workload
    _, srv = _serve_pair(workload)
    bad = [
        lambda: srv.submit(points[0], kind="knn", radius=3),   # stray radius
        lambda: srv.submit(points[0], kind="radius"),          # missing
        lambda: srv.submit(points[0], kind="radius", radius=float("nan")),
        lambda: srv.submit(points[0], kind="radius", radius=-2),
        lambda: srv.submit(queries[0], kind="bogus"),
        lambda: srv.submit(queries[0], kind="knn"),            # rect to knn
    ]
    try:
        for fn in bad:
            with pytest.raises(QueryValidationError):
                fn()
        assert srv.metrics()["queue_depth"] == 0    # nothing enqueued
    finally:
        srv.stop()


def test_serve_config_validates_k_and_kcap(workload):
    from repro.serve.spatial_serve import ServeConfig, SpatialServer

    eng = _engine("broadcast", workload)
    for kw in ({"knn_k": 0}, {"kcap": 0}, {"knn_k": -2}):
        with pytest.raises(QueryValidationError):
            SpatialServer(eng, ServeConfig(**kw)).stop()


@pytest.mark.chaos
def test_server_kinds_degrade_and_recover(workload):
    """Fast path breaks after warmup: every kind degrades to the oracle
    reference path with exact answers, then a probe on a later kind batch
    recovers the fast path."""
    from repro.serve.spatial_serve import PATH_FAST, PATH_REF

    _, queries, points, radii, _ = workload
    eng, srv = _serve_pair(workload, max_retries=1, backoff_base_s=0.0,
                           watchdog_s=5.0, probe_every=2, crosscheck_every=0)
    pr, pi = eng.placed_rects, eng.placed_ids
    try:
        # warm every kind while healthy so first-compile isn't the seam
        for kind in ("ids", "knn", "radius", "aggregate"):
            q = points[0] if kind in ("knn", "radius") else queries[0]
            t = srv.submit(
                q, kind=kind,
                radius=int(radii[0]) if kind == "radius" else None,
                deadline_s=30)
            assert srv.drain(60) and t.status == "ok" and t.path == PATH_FAST

        orig_place = srv._place
        srv._place = lambda *a, **k: (_ for _ in ()).throw(
            RuntimeError("device lost"))
        w_d, w_ki = oracle.knn_oracle(points, pr, pi, k=K)
        w_rids, w_rcnt, _ = oracle.radius_oracle(points, radii, pr, pi,
                                                 kcap=KCAP)
        for i in range(4):
            tk = srv.submit(points[i], kind="knn", deadline_s=30)
            tr = srv.submit(points[i], kind="radius", radius=int(radii[i]),
                            deadline_s=30)
            assert srv.drain(60)
            assert tk.status == "ok" and tk.path == PATH_REF
            assert np.array_equal(tk.ids, w_ki[i])
            assert np.array_equal(tk.distances, w_d[i])
            assert tr.status == "ok" and tr.path == PATH_REF
            assert tr.count == int(w_rcnt[i])
            assert np.array_equal(tr.ids, w_rids[i])
        assert srv.health == "degraded"

        srv._place = orig_place
        recovered = False
        for i in range(8):
            t = srv.submit(points[i % 4], kind="knn", deadline_s=30)
            assert srv.drain(60) and t.status == "ok"
            assert np.array_equal(t.ids, w_ki[i % 4])
            recovered = recovered or t.path == PATH_FAST
        assert recovered and srv.health == "healthy"
        m = srv.metrics()
        assert m["degradations"] >= 1 and m["recoveries"] >= 1
    finally:
        srv._place = orig_place
        srv.stop()


def test_router_kinds_end_to_end(workload):
    """Kinds pass through the router: packed payload forwarding, per-kind
    verify keeps healthy replicas active, per-kind request metrics."""
    from repro.serve.router import RouterConfig, SpatialRouter
    from repro.serve.spatial_serve import ServeConfig

    rects, queries, points, radii, tree = workload
    mesh = _mesh1()
    router = SpatialRouter(
        lambda: BroadcastEngine(tree, mesh, batch_size=64, impl="xla"),
        config=RouterConfig(num_replicas=2, crosscheck_every=1, hedge=False),
        serve_config=ServeConfig(batch_size=16, kcap=KCAP, knn_k=K,
                                 crosscheck_every=0),
    )
    router.start()
    try:
        pr = router.replicas()[0].engine.placed_rects
        pi = router.replicas()[0].engine.placed_ids
        n = 8
        tasks = []
        for i in range(n):
            tasks.append(("count", i, router.submit(
                queries[i], deadline_s=30)))
            tasks.append(("ids", i, router.submit(
                queries[i], kind="ids", deadline_s=30)))
            tasks.append(("knn", i, router.submit(
                points[i], kind="knn", deadline_s=30)))
            tasks.append(("radius", i, router.submit(
                points[i], kind="radius", radius=int(radii[i]),
                deadline_s=30)))
            tasks.append(("aggregate", i, router.submit(
                queries[i], kind="aggregate", deadline_s=30)))
        for _, _, t in tasks:
            assert t.wait(60), "router ticket timed out"

        w_counts = ref.overlap_counts_np_chunked(
            queries[:n], router.replicas()[0].server._host_rects)
        w_ids, w_icnt, w_ov = oracle.ids_oracle(queries[:n], pr, pi,
                                                kcap=KCAP)
        w_d, w_ki = oracle.knn_oracle(points[:n], pr, pi, k=K)
        w_rids, w_rcnt, _ = oracle.radius_oracle(points[:n], radii[:n],
                                                 pr, pi, kcap=KCAP)
        w_acnt, w_sums, w_bbox = oracle.aggregate_oracle(queries[:n], pr)
        for kind, i, t in tasks:
            assert t.status == "ok", (kind, i, t.status, t.reason)
            if kind == "count":
                assert t.count == int(w_counts[i])
            elif kind == "ids":
                assert t.count == int(w_icnt[i])
                assert np.array_equal(t.ids, w_ids[i])
                assert t.overflow == int(w_ov[i])
            elif kind == "knn":
                assert np.array_equal(t.ids, w_ki[i])
                assert np.array_equal(t.distances, w_d[i])
            elif kind == "radius":
                assert t.count == int(w_rcnt[i])
                assert np.array_equal(t.ids, w_rids[i])
            else:
                assert t.count == int(w_acnt[i])
                assert np.array_equal(t.aggregates["bbox"], w_bbox[i])

        m = router.metrics()
        assert m["requests"] == 5 * n
        assert m["requests_by_kind"] == {
            k: n for k in ("count", "ids", "knn", "radius", "aggregate")}
        assert m["crosschecks"] > 0
        assert all(r.state == "active" for r in router.replicas())
        with pytest.raises(QueryValidationError):
            router.submit(points[0], kind="radius")     # missing radius
    finally:
        router.stop()


# ------------------------------------------------------------- multi-device

_MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from repro import compat
    from repro.core import rtree
    from repro.core.engine import BroadcastEngine
    from repro.core.subtree import SubtreeEngine
    from repro.data import spider
    from repro.query import oracle

    assert jax.device_count() == 8
    mesh = compat.make_mesh((4, 2), ("data", "model"))
    rects = spider.gaussian(4000, seed=5)
    rng = np.random.default_rng(17)
    Q = 230           # not a batch multiple: exercises the pad/un-pad path
    queries = spider.uniform(Q, seed=23, max_size=0.02)
    points = rng.integers(0, spider.SCALE, (Q, 2)).astype(np.int32)
    radii = rng.integers(0, 60_000, Q).astype(np.int32)

    def check(name, eng):
        pr, pi = eng.placed_rects, eng.placed_ids
        live = pi >= 0
        assert np.array_equal(np.sort(pi[live]), np.arange(rects.shape[0]))
        res = eng.query_ids(queries, kcap=24)
        w_ids, w_cnt, w_ov = oracle.ids_oracle(queries, pr, pi, kcap=24)
        assert np.array_equal(res.count, w_cnt), name
        assert np.array_equal(res.ids, w_ids), name
        assert np.array_equal(res.overflow, w_ov), name
        res = eng.query_knn(points, k=5)
        w_d, w_i = oracle.knn_oracle(points, pr, pi, k=5)
        assert np.array_equal(res.ids, w_i), name
        assert np.array_equal(res.distances, w_d), name
        res = eng.query_radius(points, radii, kcap=12)
        w_ids, w_cnt, w_ov = oracle.radius_oracle(
            points, radii, pr, pi, kcap=12)
        assert np.array_equal(res.count, w_cnt), name
        assert np.array_equal(res.ids, w_ids), name
        res = eng.query_aggregate(queries)
        w_cnt, w_sums, w_bbox = oracle.aggregate_oracle(queries, pr)
        assert np.array_equal(res.count, w_cnt), name
        assert np.array_equal(res.bbox, w_bbox), name
        np.testing.assert_allclose(res.aggregates["sums"], w_sums,
                                   rtol=oracle.AGG_RTOL, atol=oracle.AGG_ATOL)
        print(name, "OK", flush=True)

    tree = rtree.build_str_3level(rects, leaf_capacity=16, fanout=8)
    check("broadcast", BroadcastEngine(tree, mesh, batch_size=128,
                                       impl="xla"))
    check("broadcast-sorted", BroadcastEngine(
        tree, mesh, batch_size=128, impl="xla", sort_queries=True))
    check("subtree", SubtreeEngine(rects, mesh, leaf_capacity=16,
                                   batch_size=128, impl="xla"))
    print("QUERY_MULTIDEV_OK")
""")


@pytest.mark.slow
def test_query_kinds_multidevice_8():
    """8 virtual devices, mesh (4, 2): cross-device offsets, psum slot
    merges, top-k merge, and aggregate combines — all four kinds
    oracle-exact, including the Morton-sorted engine."""
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO_SRC
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", _MULTIDEV_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "QUERY_MULTIDEV_OK" in r.stdout
