"""Engine-boundary query validation: every malformed input class is an
explicit error (or a documented canonicalization), never a silently wrong
count."""
import numpy as np
import pytest

from repro import compat
from repro.core import engine as beng
from repro.core import rtree, subtree
from repro.core.engine import QueryValidationError, validate_queries
from repro.data import datasets, spider
from repro.kernels import ref


def _mesh1():
    return compat.make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="module")
def small_engine():
    rects = spider.uniform(1000, seed=71, max_size=0.02)
    tree = rtree.build_str_3level(rects, leaf_capacity=16, fanout=4)
    return rects, beng.BroadcastEngine(tree, _mesh1(), batch_size=32)


@pytest.mark.parametrize("bad", [
    np.zeros((5, 3), np.int32),                 # wrong trailing dim
    np.zeros((4,), np.int32),                   # 1-D
    np.zeros((2, 2, 4), np.int32),              # 3-D
    np.array([[0, 0, np.nan, 1]]),              # NaN
    np.array([[0, 0, np.inf, 1]]),              # inf
    np.array([[0.5, 0, 1, 1]]),                 # fractional float
    np.array([[0, 0, 2**40, 1]]),               # out of int32 range
    np.array([[True, False, True, True]]),      # bool dtype
    np.array([["a", "b", "c", "d"]]),           # string dtype
])
def test_validate_queries_rejects(bad):
    with pytest.raises(QueryValidationError):
        validate_queries(bad)


def test_validate_queries_accepts_integral_floats():
    q = np.array([[0.0, 0.0, 10.0, 10.0]], np.float64)
    out = validate_queries(q)
    assert out.dtype == np.int32
    np.testing.assert_array_equal(out, [[0, 0, 10, 10]])


def test_validate_queries_canonicalizes_flipped():
    q = np.array([[10, 20, 0, 5]], np.int32)         # lo > hi on both axes
    out = validate_queries(q)
    np.testing.assert_array_equal(out, [[0, 5, 10, 20]])
    with pytest.raises(QueryValidationError):
        validate_queries(q, strict=True)


def test_validate_queries_empty_ok():
    out = validate_queries(np.zeros((0, 4), np.int64))
    assert out.shape == (0, 4) and out.dtype == np.int32


def test_engine_rejects_malformed(small_engine):
    _, eng = small_engine
    with pytest.raises(QueryValidationError):
        eng.query(np.array([[0, 0, np.nan, 1]]))
    with pytest.raises(QueryValidationError):
        eng.query(np.zeros((3, 5), np.int32))


def test_subtree_engine_rejects_malformed():
    rects = spider.gaussian(500, seed=72, max_size=0.02)
    eng = subtree.SubtreeEngine(rects, _mesh1(), leaf_capacity=32,
                                batch_size=32)
    with pytest.raises(QueryValidationError):
        eng.query(np.array([[0, 0, 1, np.inf]]))


def test_flipped_queries_count_like_canonical(small_engine):
    """Canonicalization is semantic, not cosmetic: a flipped rect counts
    exactly what its canonical twin counts (the old behavior aliased the
    EMPTY sentinel and silently returned 0)."""
    rects, eng = small_engine
    queries = datasets.make_queries(rects, 0.1, seed=73)
    flipped = queries.copy()
    flipped[:, [0, 2]] = flipped[:, [2, 0]]          # swap x corners
    flipped[:, [1, 3]] = flipped[:, [3, 1]]          # swap y corners
    got = eng.query(flipped)
    want = ref.overlap_counts_np(queries, rects)
    np.testing.assert_array_equal(got, want)
    assert int(want.sum()) > 0                       # non-trivial workload
