"""Property tests for the SPIDER skewed generators (gaussian, diagonal,
parcel): bounds, dtype, determinism under seed, and non-degenerate extent.

Runs under real hypothesis when installed, else under the deterministic
fallback registered in ``tests/conftest.py`` (seeded random examples).
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.data import spider

SKEWED = ("gaussian", "diagonal", "parcel")


def _checks(name: str, n: int, seed: int) -> np.ndarray:
    r = spider.generate(name, n, seed=seed)
    assert r.shape == (n, 4), (name, r.shape)
    assert r.dtype == np.int32, (name, r.dtype)
    assert int(r.min()) >= 0 and int(r.max()) <= spider.SCALE, name
    assert (r[:, 0] <= r[:, 2]).all() and (r[:, 1] <= r[:, 3]).all(), name
    return r


@settings(max_examples=20, deadline=None)
@given(n=st.integers(min_value=2, max_value=128),
       seed=st.integers(min_value=0, max_value=2**20))
def test_skewed_generators_invariants(n, seed):
    """Bounds, dtype, lo<=hi, and determinism-under-seed for every skewed
    distribution, over drawn (n, seed) pairs."""
    for name in SKEWED:
        a = _checks(name, n, seed)
        b = spider.generate(name, n, seed=seed)
        np.testing.assert_array_equal(a, b)      # deterministic in seed


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**20))
def test_skewed_generators_seed_sensitivity(seed):
    """Different seeds produce different datasets (the generators actually
    consume their rng, rather than collapsing to one layout)."""
    for name in SKEWED:
        a = spider.generate(name, 64, seed=seed)
        b = spider.generate(name, 64, seed=seed + 1)
        assert not np.array_equal(a, b), name


def test_skewed_generators_nondegenerate_extent():
    """The skew must not collapse the dataset to a point/line: each
    distribution's bounding box spans a meaningful fraction of the grid,
    and parcel (a space partition) tiles nearly all of it."""
    for name, min_span in (("gaussian", 0.2), ("diagonal", 0.2),
                           ("parcel", 0.9)):
        r = spider.generate(name, 500, seed=7)
        span_x = int(r[:, 2].max()) - int(r[:, 0].min())
        span_y = int(r[:, 3].max()) - int(r[:, 1].min())
        assert span_x >= min_span * spider.SCALE, (name, span_x)
        assert span_y >= min_span * spider.SCALE, (name, span_y)
        # rect extents are non-degenerate in aggregate: not every rect
        # collapses to zero area after rounding
        areas = (r[:, 2] - r[:, 0]).astype(np.int64) * \
            (r[:, 3] - r[:, 1]).astype(np.int64)
        assert int(areas.sum()) > 0, name


def test_diagonal_actually_concentrates_on_diagonal():
    """Skew sanity for the routing/load-balance work: diagonal mass lies
    near y=x (this is the distribution that exposes leaf-slice imbalance)."""
    r = spider.diagonal(2000, seed=8)
    cx = (r[:, 0].astype(np.int64) + r[:, 2]) // 2
    cy = (r[:, 1].astype(np.int64) + r[:, 3]) // 2
    near = np.abs(cx - cy) < 0.2 * spider.SCALE
    assert near.mean() > 0.8
