"""Tests for the unified tracing + metrics layer (repro.obs).

Covers the subsystem's core contracts (DESIGN.md Sec 12):

* disabled tracing is ~free (shared null span, no allocation per call);
* nested spans parent correctly, including across threads;
* JSONL export round-trips losslessly;
* the phase accounting partitions root wall time exactly (self-time model);
* histogram percentile estimates interpolate inside the covering bucket and
  stay monotone;
* the Prometheus exporter emits well-formed exposition text (golden);
* a real (tiny) engine run satisfies phase-sum ≈ wall-time, and the blocking
  per-batch harness returns positive slices;
* the report CLI selftest passes and writes artifacts;
* the pallint runtime guards export into the default registry.
"""
from __future__ import annotations

import json
import threading
import time

import numpy as np
import pytest

from repro.obs import metrics, phases, trace


@pytest.fixture
def tracer():
    """A fresh private tracer (never the module-global one)."""
    return trace.Tracer()


@pytest.fixture
def global_tracer():
    """The module-global tracer, reset and disabled on the way out so no
    test leaks enabled tracing into the instrumented library."""
    t = trace.get_tracer()
    t.reset()
    yield t
    t.disable()
    t.reset()


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


def test_disabled_span_is_shared_null_object(tracer):
    s1 = tracer.span("a")
    s2 = tracer.span("b", phase=phases.KERNEL, batch=3)
    assert s1 is s2                     # one shared no-op instance
    with s1:
        pass
    assert tracer.events() == []


def test_disabled_tracer_overhead_is_tiny(tracer):
    """The disabled hot path must cost ~one attribute check per span call."""
    n = 20_000
    t0 = time.monotonic_ns()
    for _ in range(n):
        with tracer.span("hot", phase=phases.KERNEL):
            pass
    per_call_us = (time.monotonic_ns() - t0) / n / 1e3
    # generous CI bound: a no-op context manager runs in well under 20µs
    assert per_call_us < 20.0, f"disabled span cost {per_call_us:.2f}µs/call"


def test_nested_span_parenting(tracer):
    tracer.enable()
    with tracer.span("outer", phase=phases.HOST):
        with tracer.span("inner", phase=phases.KERNEL):
            pass
        tracer.event("mark", phase=phases.HOST)
    events = {e["name"]: e for e in tracer.events()}
    assert events["outer"]["parent"] is None
    assert events["inner"]["parent"] == events["outer"]["id"]
    assert events["mark"]["parent"] == events["outer"]["id"]
    assert events["mark"]["t0_ns"] == events["mark"]["t1_ns"]
    assert events["inner"]["t0_ns"] >= events["outer"]["t0_ns"]
    assert events["inner"]["t1_ns"] <= events["outer"]["t1_ns"]


def test_span_stacks_are_thread_local(tracer):
    """Spans opened on another thread must not parent onto this thread's
    open span (and vice versa)."""
    tracer.enable()
    ready = threading.Event()
    release = threading.Event()

    def worker():
        with tracer.span("worker_root", phase=phases.KERNEL):
            ready.set()
            release.wait(5)

    with tracer.span("main_root", phase=phases.HOST):
        th = threading.Thread(target=worker)
        th.start()
        ready.wait(5)
        with tracer.span("main_child"):
            pass
        release.set()
        th.join(5)
    by_name = {e["name"]: e for e in tracer.events()}
    assert by_name["worker_root"]["parent"] is None
    assert by_name["main_child"]["parent"] == by_name["main_root"]["id"]
    assert by_name["worker_root"]["thread"] != by_name["main_root"]["thread"]


def test_many_threads_record_consistently(tracer):
    tracer.enable()
    nthreads, nspans = 8, 50

    def worker(i):
        for j in range(nspans):
            with tracer.span("w", phase=phases.HOST, tid=i, j=j):
                pass

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(nthreads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(10)
    events = tracer.events()
    assert len(events) == nthreads * nspans
    assert len({e["id"] for e in events}) == len(events)   # unique ids
    assert all(e["parent"] is None for e in events)        # roots per thread


def test_jsonl_round_trip(tracer, tmp_path):
    tracer.enable()
    with tracer.span("a", phase=phases.BUILD, n=3):
        tracer.event("e", phase=phases.HOST, why="test")
    path = str(tmp_path / "trace.jsonl")
    count = tracer.export_jsonl(path)
    assert count == 2
    assert trace.load_jsonl(path) == tracer.events()


def test_record_synthesizes_single_span(tracer):
    tracer.enable()
    tracer.record("measured", phase=phases.KERNEL, seconds=0.25, repeats=5)
    (e,) = tracer.events()
    assert e["t1_ns"] - e["t0_ns"] == pytest.approx(0.25e9, rel=1e-6)
    assert e["phase"] == phases.KERNEL
    assert e["attrs"]["repeats"] == 5


def test_reset_clears_and_restarts_ids(tracer):
    tracer.enable()
    with tracer.span("a"):
        pass
    tracer.reset()
    assert tracer.events() == []
    with tracer.span("b"):
        pass
    assert tracer.events()[0]["id"] == 1


# ---------------------------------------------------------------------------
# phase accounting
# ---------------------------------------------------------------------------


def test_breakdown_self_time_partitions_wall(tracer):
    tracer.enable()
    with tracer.span("root", phase=phases.HOST):
        with tracer.span("build", phase=phases.BUILD):
            time.sleep(0.002)
        with tracer.span("k", phase=phases.KERNEL):
            time.sleep(0.004)
    bd = phases.breakdown(tracer.events())
    total = sum(bd["seconds"].values())
    assert total == pytest.approx(bd["wall_s"], rel=1e-6, abs=1e-9)
    assert abs(sum(bd["fractions"].values()) - 1.0) < 1e-9
    assert bd["seconds"][phases.KERNEL] > bd["seconds"][phases.BUILD] > 0
    # root self-time (duration minus children) lands in host
    assert bd["seconds"][phases.HOST] >= 0


def test_breakdown_unknown_phase_folds_into_host(tracer):
    tracer.enable()
    with tracer.span("odd", phase="mystery"):
        pass
    bd = phases.breakdown(tracer.events())
    assert bd["seconds"][phases.HOST] >= 0
    assert sum(bd["seconds"].values()) == pytest.approx(bd["wall_s"],
                                                        abs=1e-9)


def test_breakdown_empty_trace():
    bd = phases.breakdown([])
    assert bd["wall_s"] == 0.0
    assert all(v == 0.0 for v in bd["seconds"].values())
    assert all(v == 0.0 for v in bd["fractions"].values())


def test_span_seconds_sums_by_name(tracer):
    tracer.enable()
    tracer.record("x", phase=phases.BUILD, seconds=0.1)
    tracer.record("x", phase=phases.BUILD, seconds=0.2)
    tracer.record("y", phase=phases.BUILD, seconds=0.5)
    events = tracer.events()
    assert phases.span_seconds(events, "x") == pytest.approx(0.3, rel=1e-6)
    assert phases.span_seconds(events, "absent") == 0.0


def test_compose_pipeline_fractions():
    per_batch = {"h2d_s": 0.001, "kernel_s": 0.01, "d2h_s": 0.0005}
    out = phases.compose_pipeline(
        build_s=0.05, place_s=0.02, per_batch=per_batch, num_batches=10,
        stream_wall_s=0.2)
    assert abs(sum(out["fractions"].values()) - 1.0) < 1e-9
    assert out["seconds"][phases.KERNEL] == pytest.approx(0.1)
    assert out["seconds"][phases.H2D] == pytest.approx(0.02 + 0.01)
    # host = stream wall minus the per-batch device slices
    assert out["seconds"][phases.HOST] == pytest.approx(0.2 - 0.115)
    # perfect overlap clamps host at zero, never negative
    tight = phases.compose_pipeline(
        build_s=0.0, place_s=0.0, per_batch=per_batch, num_batches=10,
        stream_wall_s=0.05)
    assert tight["seconds"][phases.HOST] == 0.0


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_counter_labels_and_totals():
    reg = metrics.Registry()
    c = reg.counter("events_total", "help text")
    c.inc(kind="a")
    c.inc(2, kind="a")
    c.inc(kind="b")
    assert c.value(kind="a") == 3
    assert c.total() == 4
    assert c.as_dict("kind") == {"a": 3.0, "b": 1.0}
    with pytest.raises(ValueError):
        c.inc(-1)


def test_registry_get_or_create_and_type_conflict():
    reg = metrics.Registry()
    assert reg.counter("x") is reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")
    with pytest.raises(ValueError):
        reg.counter("bad name!")


def test_histogram_rejects_bad_edges():
    with pytest.raises(ValueError):
        metrics.Histogram("h", buckets=())
    with pytest.raises(ValueError):
        metrics.Histogram("h", buckets=(1.0, 1.0, 2.0))
    metrics.Histogram("h", buckets=(0.1, 0.2, 0.4))   # strictly increasing ok


def test_histogram_percentile_interpolates_and_is_monotone():
    h = metrics.Histogram("lat", buckets=(0.01, 0.1, 1.0))
    for v in (0.002, 0.004, 0.05, 0.06, 0.07, 0.5):
        h.observe(v)
    assert h.count == 6
    assert h.mean() == pytest.approx(sum((0.002, 0.004, 0.05, 0.06, 0.07,
                                          0.5)) / 6)
    ps = [h.percentile(q) for q in (0, 25, 50, 75, 90, 99, 100)]
    assert all(a <= b + 1e-12 for a, b in zip(ps, ps[1:]))   # monotone
    # estimates stay inside the observed range (min/max clamping)
    assert 0.002 - 1e-12 <= ps[0] and ps[-1] <= 0.5 + 1e-12
    # p50 lands in the covering (0.01, 0.1] bucket
    assert 0.01 <= h.percentile(50) <= 0.1
    assert metrics.Histogram("e").percentile(50) is None     # empty


def test_histogram_overflow_bucket_capped_at_max():
    h = metrics.Histogram("lat", buckets=(0.01,))
    h.observe(5.0)
    h.observe(7.0)
    assert h.percentile(99) <= 7.0
    assert h.bucket_counts()[-1] == (float("inf"), 2)


def test_prometheus_text_golden():
    reg = metrics.Registry()
    reg.counter("events_total", "things that happened").inc(3, kind="served")
    reg.gauge("depth").set(2)
    h = reg.histogram("lat_seconds", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    want = (
        '# TYPE depth gauge\n'
        'depth 2\n'
        '# HELP events_total things that happened\n'
        '# TYPE events_total counter\n'
        'events_total{kind="served"} 3\n'
        '# TYPE lat_seconds histogram\n'
        'lat_seconds_bucket{le="0.1"} 1\n'
        'lat_seconds_bucket{le="1"} 2\n'
        'lat_seconds_bucket{le="+Inf"} 3\n'
        'lat_seconds_sum 5.55\n'
        'lat_seconds_count 3\n'
    )
    assert reg.prometheus_text() == want


def test_snapshot_is_json_serializable():
    reg = metrics.Registry()
    reg.counter("c").inc(kind="x")
    reg.histogram("h").observe(0.2)
    snap = json.loads(reg.snapshot_json())
    assert snap["c"]["kind"] == "counter"
    assert snap["h"]["count"] == 1
    assert snap["h"]["p50"] is not None


def test_aggregate_prometheus_tags_sources():
    """aggregate_prometheus merges registries into one surface: each named
    source's series gains the replica label (sorted into the label set),
    histograms included, base series stay unlabeled."""
    r0, r1, base = metrics.Registry(), metrics.Registry(), metrics.Registry()
    r0.counter("events_total", "events").inc(3, kind="served")
    r1.counter("events_total").inc(1, kind="served")
    r1.gauge("healthy").set(1)
    h = r0.histogram("lat_seconds", buckets=(0.1, 1.0))
    h.observe(0.5)
    base.counter("router_requests_total", "router admits").inc(4)
    text = metrics.aggregate_prometheus(
        {"r0": r0, "r1": r1}, label="replica", base=base)
    assert 'events_total{kind="served",replica="r0"} 3\n' in text
    assert 'events_total{kind="served",replica="r1"} 1\n' in text
    assert 'healthy{replica="r1"} 1\n' in text
    assert 'lat_seconds_bucket{le="1",replica="r0"} 1\n' in text
    assert 'lat_seconds_sum{replica="r0"} 0.5\n' in text
    assert 'lat_seconds_count{replica="r0"} 1\n' in text
    assert "router_requests_total 4\n" in text          # base: unlabeled
    # exposition format: one HELP/TYPE block per metric name, help wins
    # from the first source that has one
    assert text.count("# TYPE events_total counter") == 1
    assert "# HELP events_total events" in text


def test_aggregate_prometheus_rejects_kind_conflicts():
    a, b = metrics.Registry(), metrics.Registry()
    a.counter("x")
    b.gauge("x")
    with pytest.raises(TypeError):
        metrics.aggregate_prometheus({"a": a, "b": b})


# ---------------------------------------------------------------------------
# engine integration (small real run through the instrumented stack)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_engine():
    from repro import compat
    from repro.core import engine as beng
    from repro.core import rtree
    from repro.data import datasets, spider

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    rects = spider.uniform(3000, seed=21, max_size=0.02)
    queries = datasets.make_queries(rects, 0.4, seed=22)
    tree = rtree.build_str_3level(rects, *rtree.choose_parameters(3000, 1))
    eng = beng.BroadcastEngine(tree, mesh, batch_size=128)
    eng.query(queries[:128])        # warmup/compile outside any trace
    return eng, queries


def test_engine_run_phase_sum_matches_wall(global_tracer, tiny_engine):
    eng, queries = tiny_engine
    global_tracer.enable()
    counts = eng.query(queries)
    global_tracer.disable()
    assert counts.shape == (queries.shape[0],)
    events = global_tracer.events()
    names = {e["name"] for e in events}
    assert {"broadcast.query", "stream_batches", "stage", "dispatch",
            "sync_retrieve"} <= names
    bd = phases.breakdown(events)
    assert sum(bd["seconds"].values()) == pytest.approx(
        bd["wall_s"], rel=1e-6, abs=1e-9)
    assert bd["wall_s"] > 0
    # the pipelined loop stages and syncs on device
    assert bd["seconds"][phases.H2D] > 0
    assert bd["seconds"][phases.D2H] > 0


def test_engine_untraced_run_records_nothing(global_tracer, tiny_engine):
    eng, queries = tiny_engine
    eng.query(queries[:128])
    assert global_tracer.events() == []


def test_measure_query_phases_positive_slices(global_tracer, tiny_engine):
    from benchmarks import common as bcommon

    eng, queries = tiny_engine
    step, operands, rep_sh = bcommon.bench_step(eng)
    global_tracer.enable()
    slices = phases.measure_query_phases(
        step, operands, np.asarray(queries[:128], np.int32), rep_sh,
        repeats=2, warmup=1)
    global_tracer.disable()
    assert slices["h2d_s"] > 0
    assert slices["kernel_s"] > 0
    assert slices["d2h_s"] >= 0
    names = {e["name"] for e in global_tracer.events()}
    assert {"batch_stage", "batch_kernel", "batch_retrieve"} <= names


def test_derived_stats_broadcast_layout(tiny_engine):
    eng, queries = tiny_engine
    d = phases.derived_stats(eng.layout, len(queries), 128)
    assert d["d2h_bytes"] == len(queries) * 4
    assert d["h2d_bytes"] > d["placement_bytes"] > 0
    assert d["rect_tests"] == (len(queries) * eng.layout.rects_per_device
                               * eng.layout.num_devices)
    assert d["ops"] == d["rect_tests"] * phases.OPS_PER_RECT_TEST
    assert d["ops_per_streamed_byte"] > 0


def test_build_span_recorded(global_tracer):
    from repro.core import rtree
    from repro.data import spider

    rects = spider.uniform(2000, seed=23, max_size=0.02)
    global_tracer.enable()
    rtree.build_str_3level(rects, *rtree.choose_parameters(2000, 1))
    global_tracer.disable()
    events = global_tracer.events()
    assert phases.span_seconds(events, "build_str_3level") > 0
    (e,) = [x for x in events if x["name"] == "build_str_3level"]
    assert e["phase"] == phases.BUILD
    assert e["attrs"]["rects"] == 2000


# ---------------------------------------------------------------------------
# report CLI + guard wiring
# ---------------------------------------------------------------------------


def test_report_selftest_passes(tmp_path, capsys):
    from repro.obs import report

    out = str(tmp_path / "artifacts")
    assert report.main(["--selftest", "--out", out]) == 0
    captured = capsys.readouterr().out
    assert "selftest OK" in captured
    assert (tmp_path / "artifacts" / "trace.jsonl").exists()
    assert (tmp_path / "artifacts" / "metrics.json").exists()


def test_report_renders_trace_file(tmp_path, capsys, tracer):
    from repro.obs import report

    tracer.enable()
    with tracer.span("pipeline", phase=phases.HOST):
        with tracer.span("k", phase=phases.KERNEL):
            time.sleep(0.001)
    path = str(tmp_path / "t.jsonl")
    tracer.export_jsonl(path)
    assert report.main([path]) == 0
    out = capsys.readouterr().out
    assert "kernel" in out and "total" in out
    assert report.main([path, "--json"]) == 0
    parsed = json.loads(capsys.readouterr().out)
    assert abs(sum(parsed["fractions"].values()) - 1.0) < 1e-9


def test_report_unreadable_trace_exits_nonzero(tmp_path, capsys):
    from repro.obs import report

    assert report.main([str(tmp_path / "missing.jsonl")]) == 1
    assert "cannot read" in capsys.readouterr().err


def test_guard_violation_exports_to_default_registry(tiny_engine):
    from repro.analysis.pallint import guards

    eng, queries = tiny_engine
    reg = metrics.get_registry()
    before = reg.counter(
        "pallint_implicit_transfers_total",
        "GR302 implicit device->host transfers caught by the "
        "trace guard").total()
    # the CPU backend is unified-memory (the real transfer guard never
    # fires), so exercise the rebadge path the same way test_pallint does
    with pytest.raises(guards.GuardViolation, match="GR302"):
        with guards.steady_state(where="test_obs"):
            raise RuntimeError(
                "Disallowed device-to-host transfer: int32[16]")
    after = reg.counter("pallint_implicit_transfers_total").total()
    assert after == before + 1
    assert reg.counter(
        "pallint_implicit_transfers_total").value(where="test_obs") >= 1
    # the clean path under the same guard leaves the counter alone
    with guards.steady_state(entrypoints={"step": eng._step},
                             where="test_obs"):
        eng.query(queries[:128])
    assert reg.counter("pallint_implicit_transfers_total").total() == after
    # compile-count gauge exported for the watched entrypoint
    text = reg.prometheus_text()
    assert 'pallint_compile_count{entrypoint="step"}' in text
