"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracle.

The Pallas kernels run in interpret mode on this CPU container; BlockSpecs
target TPU VMEM tiles.  Every path must be exact-int equal to ref.py.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.kernels import ops, ref


def _rand(n, seed, scale=100_000, degenerate=False):
    rng = np.random.default_rng(seed)
    lo = rng.integers(0, scale, (n, 2))
    hi_off = rng.integers(0, scale // 20 + 1, (n, 2))
    if degenerate:
        hi_off[: n // 4] = 0
    return np.concatenate([lo, lo + hi_off], axis=1).astype(np.int32)


@pytest.mark.parametrize("q,r", [(1, 1), (3, 5), (17, 33), (64, 64),
                                 (100, 257), (513, 129)])
@pytest.mark.parametrize("tq,tr", [(8, 16), (16, 8), (32, 32)])
def test_pallas_shape_sweep(q, r, tq, tr):
    queries = _rand(q, seed=q * 1000 + r)
    rects = _rand(r, seed=q * 7 + r * 3, degenerate=True)
    want = np.asarray(ref.overlap_counts_ref(jnp.asarray(queries),
                                             jnp.asarray(rects)))
    got = np.asarray(ops.overlap_counts(
        jnp.asarray(queries), jnp.asarray(rects), impl="pallas",
        tq=tq, tr=tr))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("impl", ["pallas", "xla"])
def test_phase1_mask_gates(impl):
    queries = _rand(40, seed=1)
    rects = _rand(200, seed=2)
    mask = (np.arange(40) % 3 == 0).astype(np.int32)
    want = np.asarray(ref.overlap_counts_ref(jnp.asarray(queries),
                                             jnp.asarray(rects)))
    want = want * mask
    got = np.asarray(ops.overlap_counts(
        jnp.asarray(queries), jnp.asarray(rects), jnp.asarray(mask),
        impl=impl, tq=8, tr=16))
    np.testing.assert_array_equal(got, want)


def test_sparse_kernel_matches():
    queries = _rand(64, seed=3, scale=10_000)
    rects = _rand(512, seed=4, scale=10_000, degenerate=True)
    want = np.asarray(ref.overlap_counts_ref(jnp.asarray(queries),
                                             jnp.asarray(rects)))
    got = np.asarray(ops.overlap_counts_sparse_host(
        queries, rects, tq=16, tr=32))
    np.testing.assert_array_equal(got, want)


def test_sparse_kernel_prunes():
    """Spatially separated query/rect clusters → most tiles pruned, counts
    still exact."""
    rng = np.random.default_rng(5)
    # rects in [0, 1000]^2, queries half in-range half far away
    rects = _rand(256, seed=6, scale=1000)
    far = _rand(32, seed=7, scale=1000) + 10_000_000
    near = _rand(32, seed=8, scale=1000)
    queries = np.concatenate([near, far]).astype(np.int32)
    want = np.asarray(ref.overlap_counts_ref(jnp.asarray(queries),
                                             jnp.asarray(rects)))
    assert want[32:].sum() == 0
    got = np.asarray(ops.overlap_counts_sparse_host(
        queries, rects, tq=8, tr=32))
    np.testing.assert_array_equal(got, want)
    got2 = np.asarray(ops.overlap_counts(
        jnp.asarray(queries), jnp.asarray(rects), impl="pallas",
        tq=8, tr=32))
    np.testing.assert_array_equal(got2, want)


def test_empty_padding_never_counts():
    queries = _rand(8, seed=9)
    rects = np.asarray(ops.pad_rects_to(jnp.asarray(_rand(10, seed=10)), 64))
    assert rects.shape[0] == 64
    want = np.asarray(ref.overlap_counts_np(queries, rects[:10]))
    got = np.asarray(ops.overlap_counts(
        jnp.asarray(queries), jnp.asarray(rects), impl="pallas",
        tq=8, tr=16))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(
    q=st.integers(1, 40),
    r=st.integers(1, 80),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_pallas_equals_oracle(q, r, seed):
    rng = np.random.default_rng(seed)
    # sort the two corner points per coordinate → rows are
    # [xmin, ymin, xmax, ymax]
    queries = np.sort(rng.integers(-1000, 1000, (q, 2, 2)), axis=1)
    queries = queries.reshape(q, 4).astype(np.int32)
    rects = np.sort(rng.integers(-1000, 1000, (r, 2, 2)), axis=1)
    rects = rects.reshape(r, 4).astype(np.int32)
    want = ref.overlap_counts_np(queries, rects)
    got = np.asarray(ops.overlap_counts(
        jnp.asarray(queries), jnp.asarray(rects), impl="pallas",
        tq=8, tr=8))
    np.testing.assert_array_equal(got, want)
    got_xla = np.asarray(ops.overlap_counts(
        jnp.asarray(queries), jnp.asarray(rects), impl="xla"))
    np.testing.assert_array_equal(got_xla, want)
