"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracle.

The Pallas kernels run in interpret mode on this CPU container; BlockSpecs
target TPU VMEM tiles.  Every path must be exact-int equal to ref.py.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.kernels import ops, ref


def _rand(n, seed, scale=100_000, degenerate=False):
    rng = np.random.default_rng(seed)
    lo = rng.integers(0, scale, (n, 2))
    hi_off = rng.integers(0, scale // 20 + 1, (n, 2))
    if degenerate:
        hi_off[: n // 4] = 0
    return np.concatenate([lo, lo + hi_off], axis=1).astype(np.int32)


@pytest.mark.parametrize("q,r", [(1, 1), (3, 5), (17, 33), (64, 64),
                                 (100, 257), (513, 129)])
@pytest.mark.parametrize("tq,tr", [(8, 16), (16, 8), (32, 32)])
def test_pallas_shape_sweep(q, r, tq, tr):
    queries = _rand(q, seed=q * 1000 + r)
    rects = _rand(r, seed=q * 7 + r * 3, degenerate=True)
    want = np.asarray(ref.overlap_counts_ref(jnp.asarray(queries),
                                             jnp.asarray(rects)))
    got = np.asarray(ops.overlap_counts(
        jnp.asarray(queries), jnp.asarray(rects), impl="pallas",
        tq=tq, tr=tr))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("impl", ["pallas", "xla"])
def test_phase1_mask_gates(impl):
    queries = _rand(40, seed=1)
    rects = _rand(200, seed=2)
    mask = (np.arange(40) % 3 == 0).astype(np.int32)
    want = np.asarray(ref.overlap_counts_ref(jnp.asarray(queries),
                                             jnp.asarray(rects)))
    want = want * mask
    got = np.asarray(ops.overlap_counts(
        jnp.asarray(queries), jnp.asarray(rects), jnp.asarray(mask),
        impl=impl, tq=8, tr=16))
    np.testing.assert_array_equal(got, want)


def test_sparse_kernel_matches():
    queries = _rand(64, seed=3, scale=10_000)
    rects = _rand(512, seed=4, scale=10_000, degenerate=True)
    want = np.asarray(ref.overlap_counts_ref(jnp.asarray(queries),
                                             jnp.asarray(rects)))
    got = np.asarray(ops.overlap_counts_sparse_host(
        queries, rects, tq=16, tr=32))
    np.testing.assert_array_equal(got, want)


def test_sparse_kernel_prunes():
    """Spatially separated query/rect clusters → most tiles pruned, counts
    still exact."""
    rng = np.random.default_rng(5)
    # rects in [0, 1000]^2, queries half in-range half far away
    rects = _rand(256, seed=6, scale=1000)
    far = _rand(32, seed=7, scale=1000) + 10_000_000
    near = _rand(32, seed=8, scale=1000)
    queries = np.concatenate([near, far]).astype(np.int32)
    want = np.asarray(ref.overlap_counts_ref(jnp.asarray(queries),
                                             jnp.asarray(rects)))
    assert want[32:].sum() == 0
    got = np.asarray(ops.overlap_counts_sparse_host(
        queries, rects, tq=8, tr=32))
    np.testing.assert_array_equal(got, want)
    got2 = np.asarray(ops.overlap_counts(
        jnp.asarray(queries), jnp.asarray(rects), impl="pallas",
        tq=8, tr=32))
    np.testing.assert_array_equal(got2, want)


def test_empty_padding_never_counts():
    queries = _rand(8, seed=9)
    rects = np.asarray(ops.pad_rects_to(jnp.asarray(_rand(10, seed=10)), 64))
    assert rects.shape[0] == 64
    want = np.asarray(ref.overlap_counts_np(queries, rects[:10]))
    got = np.asarray(ops.overlap_counts(
        jnp.asarray(queries), jnp.asarray(rects), impl="pallas",
        tq=8, tr=16))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(
    q=st.integers(1, 40),
    r=st.integers(1, 80),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_pallas_equals_oracle(q, r, seed):
    rng = np.random.default_rng(seed)
    # sort the two corner points per coordinate → rows are
    # [xmin, ymin, xmax, ymax]
    queries = np.sort(rng.integers(-1000, 1000, (q, 2, 2)), axis=1)
    queries = queries.reshape(q, 4).astype(np.int32)
    rects = np.sort(rng.integers(-1000, 1000, (r, 2, 2)), axis=1)
    rects = rects.reshape(r, 4).astype(np.int32)
    want = ref.overlap_counts_np(queries, rects)
    got = np.asarray(ops.overlap_counts(
        jnp.asarray(queries), jnp.asarray(rects), impl="pallas",
        tq=8, tr=8))
    np.testing.assert_array_equal(got, want)
    got_xla = np.asarray(ops.overlap_counts(
        jnp.asarray(queries), jnp.asarray(rects), impl="xla"))
    np.testing.assert_array_equal(got_xla, want)


# ---------------------------------------------------------------------------
# impl="sparse" routing + fused-Phase-1 paths on adversarial layouts
# ---------------------------------------------------------------------------

EMPTY = np.array([2**31 - 1, 2**31 - 1, -2**31, -2**31], np.int32)


def _global_cover(rects, pad_to=3):
    """A (pad_to, 4) cover set: the global MBR plus EMPTY sentinel padding."""
    cov = np.array([[rects[:, 0].min(), rects[:, 1].min(),
                     rects[:, 2].max(), rects[:, 3].max()]], np.int32)
    return np.concatenate([cov, np.tile(EMPTY, (pad_to - 1, 1))])


def _fused_operands(rects, tr):
    rp = np.asarray(ops.pad_rects_to(jnp.asarray(rects), tr))
    rmbrs = np.asarray(ops.tile_mbrs(jnp.asarray(rp), tr))
    return jnp.asarray(np.ascontiguousarray(rp.T)), jnp.asarray(rmbrs)


@pytest.mark.parametrize("impl", ["pallas", "sparse", "xla"])
def test_impl_sparse_routes_and_matches(impl):
    """impl="sparse" must actually run (historically it silently fell
    through to the dense Pallas path) and stay exact-int equal to ref."""
    queries = _rand(50, seed=11, scale=5000)
    rects = _rand(200, seed=12, scale=5000, degenerate=True)
    want = np.asarray(ref.overlap_counts_ref(jnp.asarray(queries),
                                             jnp.asarray(rects)))
    got = np.asarray(ops.overlap_counts(
        jnp.asarray(queries), jnp.asarray(rects), impl=impl, tq=8, tr=32))
    np.testing.assert_array_equal(got, want)


def test_unknown_impl_raises():
    queries = _rand(8, seed=13)
    rects = _rand(16, seed=14)
    with pytest.raises(ValueError, match="unknown impl"):
        ops.overlap_counts(jnp.asarray(queries), jnp.asarray(rects),
                           impl="dense")


def test_sparse_mask_gates():
    """Phase-1 mask must gate the sparse kernel exactly like the others."""
    queries = _rand(40, seed=15)
    rects = _rand(128, seed=16)
    mask = (np.arange(40) % 2 == 0).astype(np.int32)
    want = np.asarray(ref.overlap_counts_ref(jnp.asarray(queries),
                                             jnp.asarray(rects))) * mask
    got = np.asarray(ops.overlap_counts(
        jnp.asarray(queries), jnp.asarray(rects), jnp.asarray(mask),
        impl="sparse", tq=8, tr=16))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("impl", ["pallas", "sparse", "xla"])
def test_fused_all_empty_tail_tiles(impl):
    """A rect array dominated by EMPTY-padded tail tiles: every padded tile
    gets the EMPTY MBR, is never active, and never counts."""
    rects = _rand(10, seed=17, scale=1000)
    rects = np.concatenate([rects, np.tile(EMPTY, (246, 1))])  # 8 tiles of 32
    queries = _rand(24, seed=18, scale=1200)
    want = np.asarray(ref.overlap_counts_ref(jnp.asarray(queries),
                                             jnp.asarray(rects[:10])))
    r_coords, rmbrs = _fused_operands(rects, 32)
    got = np.asarray(ops.overlap_counts_fused(
        jnp.asarray(queries), r_coords, rmbrs,
        jnp.asarray(_global_cover(rects[:10])), impl=impl, tq=8, tr=32))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("impl", ["pallas", "sparse", "xla"])
def test_fused_query_tile_zero_active(impl):
    """Whole query tiles with zero active rect tiles (all queries far away)
    must come back exactly zero — the sparse kernel's j<nactive guard and the
    dense kernel's tile gate both short-circuit, but the output block still
    has to be initialised."""
    rects = _rand(96, seed=19, scale=1000)
    near = _rand(16, seed=20, scale=1000)
    far = _rand(16, seed=21, scale=1000) + 50_000_000
    queries = np.concatenate([far[:8], near, far[8:]]).astype(np.int32)
    want = np.asarray(ref.overlap_counts_ref(jnp.asarray(queries),
                                             jnp.asarray(rects)))
    assert want[:8].sum() == 0 and want[24:].sum() == 0
    r_coords, rmbrs = _fused_operands(rects, 32)
    got = np.asarray(ops.overlap_counts_fused(
        jnp.asarray(queries), r_coords, rmbrs,
        jnp.asarray(_global_cover(rects)), impl=impl, tq=8, tr=32))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("impl", ["pallas", "sparse", "xla"])
def test_fused_partial_covers_gate_per_query(impl):
    """Covers that deliberately exclude part of the space: the fused Phase-1
    filter must zero exactly the queries that miss every cover — identical
    semantics to the unfused mask across every impl."""
    rects = _rand(64, seed=22, scale=2000)
    queries = _rand(32, seed=23, scale=4000)
    covers = np.array([[0, 0, 1000, 1000],
                       [1500, 1500, 1800, 1800]], np.int32)
    mask = np.asarray(ref.rect_overlap(
        jnp.asarray(queries)[:, None, :], jnp.asarray(covers)[None]))
    mask = mask.any(axis=1)
    want = np.asarray(ref.overlap_counts_ref(jnp.asarray(queries),
                                             jnp.asarray(rects)))
    want = np.where(mask, want, 0)
    r_coords, rmbrs = _fused_operands(rects, 16)
    got = np.asarray(ops.overlap_counts_fused(
        jnp.asarray(queries), r_coords, rmbrs, jnp.asarray(covers),
        impl=impl, tq=8, tr=16))
    np.testing.assert_array_equal(got, want)


def test_build_active_tiles_vectorized_matches_bruteforce():
    """The argsort-based construction equals the per-row nonzero scan."""
    rng = np.random.default_rng(24)
    qmbrs = np.sort(rng.integers(0, 500, (13, 2, 2)), axis=1)
    qmbrs = qmbrs.reshape(13, 4).astype(np.int32)
    rmbrs = np.sort(rng.integers(0, 500, (9, 2, 2)), axis=1)
    rmbrs = rmbrs.reshape(9, 4).astype(np.int32)
    nactive, tile_ids = ops.build_active_tiles(qmbrs, rmbrs)
    qo = ops._active_matrix_np(qmbrs, rmbrs)
    for i in range(13):
        ids = np.nonzero(qo[i])[0]
        assert nactive[i] == ids.size
        np.testing.assert_array_equal(tile_ids[i, :ids.size], ids)
        assert (tile_ids[i, ids.size:] == 0).all()
    # device twin agrees (full static width, dead entries zeroed)
    na_d, tid_d = ops.build_active_tiles_device(
        jnp.asarray(qmbrs), jnp.asarray(rmbrs))
    np.testing.assert_array_equal(np.asarray(na_d), nactive)
    np.testing.assert_array_equal(
        np.asarray(tid_d)[:, :tile_ids.shape[1]], tile_ids)
