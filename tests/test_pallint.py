"""pallint self-tests: each rule class must fire on a synthetic violation,
stay quiet on clean code, honor suppressions, and the runtime guards must
catch a real recompile / implicit transfer.
"""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis.pallint import cli, contracts, guards
from repro.analysis.pallint.core import lint_file, registry

REPO = os.path.join(os.path.dirname(__file__), "..")
SRC_PATH = "src/repro/fake.py"      # fake path that lands in SRC scope
TEST_PATH = "tests/test_fake.py"    # fake path outside SRC scope


def _rules(src, path=SRC_PATH):
    return [f.rule for f in lint_file(path, src=src)]


def test_registry_has_full_catalog():
    ids = set(registry())
    assert {"PL101", "PL102", "PL103", "PL104", "PL105", "PL106", "PL107",
            "PL108", "PL109", "PL110", "PL111", "PL112", "PL113", "PC201",
            "PC202", "PC203", "PC204"} <= ids


# --- PL1xx doctrine rules --------------------------------------------------

def test_pl101_host_sync_in_jit():
    src = (
        "import jax\nimport numpy as np\n"
        "@jax.jit\n"
        "def step(x):\n"
        "    return np.asarray(x)\n"
    )
    assert "PL101" in _rules(src)
    # same call outside a jit context: PL101 stays quiet
    clean = "import numpy as np\ndef host(x):\n    return np.asarray(x)\n"
    assert "PL101" not in _rules(clean)


def test_pl101_item_and_block_until_ready():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def step(x):\n"
        "    y = x.item()\n"
        "    x.block_until_ready()\n"
        "    return y\n"
    )
    assert _rules(src).count("PL101") == 2


def test_pl102_stray_block_until_ready():
    src = "def run(x):\n    x.block_until_ready()\n    return x\n"
    assert "PL102" in _rules(src)
    # suppression marks the sanctioned end-of-set sync
    ok = ("def run(x):\n"
          "    x.block_until_ready()    # pallint: disable=PL102\n"
          "    return x\n")
    assert "PL102" not in _rules(ok)
    # SRC-scope rule: tests may sync freely
    assert "PL102" not in _rules(src, path=TEST_PATH)


def test_pl103_for_loop_over_device_array():
    src = (
        "import jax.numpy as jnp\n"
        "a = jnp.arange(8)\n"
        "def run():\n"
        "    out = 0\n"
        "    for v in a:\n"
        "        out += v\n"
        "    return out\n"
    )
    assert "PL103" in _rules(src)
    clean = "def run(xs):\n    for v in xs:\n        pass\n"
    assert "PL103" not in _rules(clean)


def test_pl104_undeclared_donation():
    src = (
        "import jax\n"
        "def make_query_step(f):\n"
        "    return jax.jit(f)\n"
    )
    assert "PL104" in _rules(src)
    # explicit empty tuple is the audited opt-out
    ok = ("import jax\n"
          "def make_query_step(f):\n"
          "    return jax.jit(f, donate_argnums=())\n")
    assert "PL104" not in _rules(ok)
    # non-step builders may jit freely
    other = "import jax\ndef build(f):\n    return jax.jit(f)\n"
    assert "PL104" not in _rules(other)


def test_pl105_dynamic_shape_hazard():
    src = (
        "import jax.numpy as jnp\n"
        "def run(n):\n"
        "    return jnp.zeros(int(n))\n"
    )
    assert "PL105" in _rules(src)
    ok = ("import jax.numpy as jnp\n"
          "def run(n):\n"
          "    return jnp.zeros(n)\n")
    assert "PL105" not in _rules(ok)


def test_pl106_mutable_default():
    assert "PL106" in _rules("def f(a=[]):\n    return a\n")
    assert "PL106" not in _rules("def f(a=()):\n    return a\n")


def test_pl107_bare_except():
    src = "def f():\n    try:\n        g()\n    except:\n        pass\n"
    assert "PL107" in _rules(src)


def test_pl108_device_host_bounce():
    src = (
        "import numpy as np\nimport jax.numpy as jnp\n"
        "def f(x):\n"
        "    return np.asarray(jnp.asarray(x) + 1)\n"
    )
    assert "PL108" in _rules(src)
    clean = ("import numpy as np\n"
             "def f(x):\n    return np.asarray(x)\n")
    assert "PL108" not in _rules(clean)


def test_pl109_int64_dtype():
    src = "import numpy as np\ndef f(x):\n    return x.astype(np.int64)\n"
    assert "PL109" in _rules(src)
    ok = ("import numpy as np\n"
          "def f(x):\n"
          "    return x.astype(np.int64)    # pallint: disable=PL109\n")
    assert "PL109" not in _rules(ok)


SERVE_PATH = "src/repro/serve/fake.py"   # fake path inside the serve tree

_WHILE_TRUE_NO_EXIT = (
    "def run(q):\n"
    "    while True:\n"
    "        q.pump()\n"
)

_EXCEPT_CONTINUE = (
    "def run(q):\n"
    "    while True:\n"
    "        try:\n"
    "            q.pump()\n"
    "        except RuntimeError:\n"
    "            continue\n"
)


def test_pl110_while_true_without_exit():
    assert "PL110" in _rules(_WHILE_TRUE_NO_EXIT, path=SERVE_PATH)
    # a break makes the loop bounded-by-construction: quiet
    ok = ("def run(q):\n"
          "    while True:\n"
          "        if q.stopped():\n"
          "            break\n"
          "        q.pump()\n")
    assert "PL110" not in _rules(ok, path=SERVE_PATH)
    # a non-constant condition is already an exit: quiet
    cond = ("def run(q):\n"
            "    while not q.stopped():\n"
            "        q.pump()\n")
    assert "PL110" not in _rules(cond, path=SERVE_PATH)


def test_pl110_except_and_continue_retry():
    assert "PL110" in _rules(_EXCEPT_CONTINUE, path=SERVE_PATH)
    # the same retry shape under a bounded for-loop is the sanctioned idiom
    ok = ("def run(q, tries):\n"
          "    for attempt in range(tries):\n"
          "        try:\n"
          "            return q.pump()\n"
          "        except RuntimeError:\n"
          "            continue\n"
          "    raise TimeoutError\n")
    assert "PL110" not in _rules(ok, path=SERVE_PATH)


def test_pl110_scoped_to_serve_tree():
    # same patterns outside src/**/serve/: other rules' territory, PL110 quiet
    assert "PL110" not in _rules(_WHILE_TRUE_NO_EXIT, path=SRC_PATH)
    assert "PL110" not in _rules(_EXCEPT_CONTINUE, path=TEST_PATH)


def test_pl110_suppression():
    ok = ("def run(q):\n"
          "    while True:    # pallint: disable=PL110\n"
          "        q.pump()\n")
    assert "PL110" not in _rules(ok, path=SERVE_PATH)


_WALL_CLOCK = (
    "import time\n"
    "def stamp():\n"
    "    return time.time()\n"
)

_HOT_PRINT = (
    "def pump(q):\n"
    "    print('served', q)\n"
)


def test_pl111_wall_clock_in_hot_path():
    for hot in ("src/repro/core/fake.py", "src/repro/serve/fake.py",
                "src/repro/kernels/fake.py"):
        assert "PL111" in _rules(_WALL_CLOCK, path=hot)
    # monotonic clocks are the sanctioned hot-path timebase: quiet
    ok = ("import time\n"
          "def stamp():\n"
          "    return time.monotonic_ns()\n")
    assert "PL111" not in _rules(ok, path=SERVE_PATH)


def test_pl111_print_in_hot_path():
    assert "PL111" in _rules(_HOT_PRINT, path="src/repro/core/fake.py")


def test_pl111_scoped_to_hot_path_modules():
    # wall clock + print outside core/serve/kernels: PL111 stays quiet
    assert "PL111" not in _rules(_WALL_CLOCK, path="src/repro/data/fake.py")
    assert "PL111" not in _rules(_HOT_PRINT, path=SRC_PATH)
    assert "PL111" not in _rules(_WALL_CLOCK, path=TEST_PATH)


def test_pl111_suppression():
    ok = ("import time\n"
          "def stamp():\n"
          "    return time.time()    # pallint: disable=PL111\n")
    assert "PL111" not in _rules(ok, path=SERVE_PATH)


_SILENT_FAILOVER = (
    "def serve(task, primary, backup):\n"
    "    try:\n"
    "        return primary.submit(task)\n"
    "    except RuntimeError:\n"
    "        return backup.submit(task)\n"
)


def test_pl112_silent_failover():
    assert "PL112" in _rules(_SILENT_FAILOVER, path=SERVE_PATH)
    # a reroute() call without recording is the same violation
    reroute = ("def serve(task, pool):\n"
               "    try:\n"
               "        return pool.primary(task)\n"
               "    except RuntimeError:\n"
               "        return pool.reroute(task)\n")
    assert "PL112" in _rules(reroute, path=SERVE_PATH)


def test_pl112_recorded_failover_ok():
    # counter increment inside the handler: observable, quiet
    inc = ("def serve(task, primary, backup, failovers):\n"
           "    try:\n"
           "        return primary.submit(task)\n"
           "    except RuntimeError:\n"
           "        failovers.inc(replica=backup.name)\n"
           "        return backup.submit(task)\n")
    assert "PL112" not in _rules(inc, path=SERVE_PATH)
    # trace event: quiet
    event = ("from repro.obs import trace\n"
             "def serve(task, primary, backup):\n"
             "    try:\n"
             "        return primary.submit(task)\n"
             "    except RuntimeError:\n"
             "        trace.event('router.failover')\n"
             "        return backup.submit(task)\n")
    assert "PL112" not in _rules(event, path=SERVE_PATH)
    # a _record_* helper (the router's idiom): quiet
    helper = ("def serve(self, task, primary, backup):\n"
              "    try:\n"
              "        return primary.submit(task)\n"
              "    except RuntimeError as e:\n"
              "        self._record_failover(backup, e)\n"
              "        return backup.submit(task)\n")
    assert "PL112" not in _rules(helper, path=SERVE_PATH)
    # an except handler with no reroute at all: not failover, quiet
    plain = ("def serve(task, primary):\n"
             "    try:\n"
             "        return primary.submit(task)\n"
             "    except RuntimeError:\n"
             "        return None\n")
    assert "PL112" not in _rules(plain, path=SERVE_PATH)


def test_pl112_scoped_to_serve_tree():
    assert "PL112" not in _rules(_SILENT_FAILOVER, path=SRC_PATH)
    assert "PL112" not in _rules(_SILENT_FAILOVER, path=TEST_PATH)


def test_pl112_suppression():
    ok = ("def serve(task, primary, backup):\n"
          "    try:\n"
          "        return primary.submit(task)\n"
          "    except RuntimeError:    # pallint: disable=PL112\n"
          "        return backup.submit(task)\n")
    assert "PL112" not in _rules(ok, path=SERVE_PATH)


QUERY_PATH = "src/repro/query/fake.py"   # fake path inside a query tree

_MASK_D2H = (
    "import numpy as np\n"
    "import jax.numpy as jnp\n"
    "def candidates(queries, rects):\n"
    "    hit = (queries[:, None, 0] <= rects[None, :, 2])\n"
    "    return np.asarray(jnp.logical_and(hit, hit))\n"
)


def test_pl113_candidate_mask_d2h():
    assert "PL113" in _rules(_MASK_D2H, path=QUERY_PATH)
    # an inline jnp comparison pulled to the host is the same violation
    cmp = ("import numpy as np\n"
           "import jax.numpy as jnp\n"
           "def candidates(q, r):\n"
           "    return np.asarray(jnp.asarray(q)[:, None] <= r[None, :])\n")
    assert "PL113" in _rules(cmp, path=QUERY_PATH)
    # device_get of a bitwise-combined device mask: same violation
    dget = ("import jax\nimport jax.numpy as jnp\n"
            "def candidates(a, b):\n"
            "    return jax.device_get(jnp.asarray(a) & jnp.asarray(b))\n")
    assert "PL113" in _rules(dget, path=QUERY_PATH)


def test_pl113_quiet_on_legit_transfers():
    # pulling the fixed-size (Q, Kcap) ID buffer is the sanctioned path
    ids = ("import numpy as np\n"
           "def retrieve(slots):\n"
           "    return np.asarray(slots) - 1\n")
    assert "PL113" not in _rules(ids, path=QUERY_PATH)
    # pure-NumPy oracles compare on the host by design — no jnp, quiet
    oracle = ("import numpy as np\n"
              "def overlap(q, r):\n"
              "    return np.asarray((q[:, None, 0] <= r[None, :, 2]))\n")
    assert "PL113" not in _rules(oracle, path=QUERY_PATH)
    # device masks that *stay* on device are fine
    on_dev = ("import jax.numpy as jnp\n"
              "def hits(q, r):\n"
              "    return jnp.logical_and(q <= r, r >= 0)\n")
    assert "PL113" not in _rules(on_dev, path=QUERY_PATH)


def test_pl113_scoped_to_query_tree():
    assert "PL113" not in _rules(_MASK_D2H, path=SRC_PATH)
    assert "PL113" not in _rules(_MASK_D2H, path=SERVE_PATH)
    assert "PL113" not in _rules(_MASK_D2H, path=TEST_PATH)


def test_pl113_suppression():
    ok = (
        "import numpy as np\n"
        "import jax.numpy as jnp\n"
        "def candidates(q, r):\n"
        "    hit = jnp.asarray(q)[:, None] <= r[None, :]\n"
        "    return np.asarray(hit)    # pallint: disable=PL113\n"
    )
    assert "PL113" not in _rules(ok, path=QUERY_PATH)


def test_file_level_suppression():
    src = ("# pallint-file: disable=PL109\n"
           "import numpy as np\n"
           "A = np.int64\nB = np.int64\n")
    assert "PL109" not in _rules(src)


def test_syntax_error_reported_not_raised():
    findings = lint_file(SRC_PATH, src="def f(:\n")
    assert [f.rule for f in findings] == ["PL000"]


# --- PC2xx Pallas contract rules -------------------------------------------

_PALLAS_PRELUDE = (
    "import jax\n"
    "import jax.numpy as jnp\n"
    "from jax.experimental import pallas as pl\n"
    "def _k(x_ref, o_ref):\n"
    "    o_ref[...] = x_ref[...]\n"
)


def _pallas_src(in_map="lambda i, j: (i, 0)", grid="(2, 2)",
                kernel="_k", extra=""):
    return (
        _PALLAS_PRELUDE
        + "def wrapper(x):\n"
        + extra
        + "    return pl.pallas_call(\n"
        f"        {kernel},\n"
        f"        grid={grid},\n"
        f"        in_specs=[pl.BlockSpec((8, 8), {in_map})],\n"
        "        out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),\n"
        "        out_shape=jax.ShapeDtypeStruct((16, 16), jnp.int32),\n"
        "    )(x)\n"
    )


def test_pc_rules_quiet_on_wellformed_site():
    assert not [r for r in _rules(_pallas_src()) if r.startswith("PC")]


def test_pc201_index_map_arity():
    assert "PC201" in _rules(_pallas_src(in_map="lambda i: (i, 0)"))


def test_pc202_index_map_form():
    assert "PC202" in _rules(_pallas_src(in_map="lambda i, j: (i + 1, 0)"))
    # wrong element count for the block rank
    assert "PC202" in _rules(_pallas_src(in_map="lambda i, j: (i,)"))


def test_pc203_kernel_signature():
    src = _pallas_src() + (
        "def _k3(a_ref, b_ref, o_ref):\n"
        "    o_ref[...] = a_ref[...]\n"
    )
    src = src.replace("pl.pallas_call(\n        _k,",
                      "pl.pallas_call(\n        _k3,")
    assert "PC203" in _rules(src)


def test_pc204_tile_divisibility():
    bad = _pallas_src(grid="(g, 2)",
                      extra="    n = x.shape[0]\n"
                            "    t = 8\n"
                            "    g = n // t\n")
    assert "PC204" in _rules(bad)
    good = _pallas_src(grid="(g, 2)",
                       extra="    n = x.shape[0]\n"
                             "    t = 8\n"
                             "    assert n % t == 0\n"
                             "    g = n // t\n")
    assert "PC204" not in _rules(good)


def test_pc205_coverage(tmp_path):
    lib = tmp_path / "src"
    lib.mkdir()
    (lib / "k.py").write_text(_pallas_src())
    tdir = tmp_path / "tests"
    tdir.mkdir()
    (tdir / "test_k.py").write_text("# no reference here\n")
    found = contracts.coverage_findings([str(lib)], [str(tdir)])
    assert [f.rule for f in found] == ["PC205"]
    report = contracts.coverage_report([str(lib)], [str(tdir)])
    assert report["missing"] == ["wrapper"]
    (tdir / "test_k.py").write_text("from k import wrapper\nwrapper(None)\n")
    assert contracts.coverage_findings([str(lib)], [str(tdir)]) == []


# --- GR3xx runtime guards --------------------------------------------------

def test_gr301_recompile_detected():
    f = jax.jit(lambda x: x + 1)
    jax.block_until_ready(f(jnp.zeros((2,), jnp.float32)))    # warm
    with pytest.raises(guards.GuardViolation, match="GR301"):
        with guards.steady_state(entrypoints={"f": f}, transfers=False):
            f(jnp.zeros((3,), jnp.float32))                   # shape drift


def test_gr301_quiet_when_warm():
    f = jax.jit(lambda x: x * 2)
    x = jnp.zeros((4,), jnp.float32)
    jax.block_until_ready(f(x))
    with guards.steady_state(entrypoints={"f": f}, transfers=False):
        f(x)


@pytest.mark.skipif(
    jax.default_backend() == "cpu",
    reason="CPU backend is unified memory: d2h is zero-copy, the transfer "
           "guard never fires (it does on TPU/GPU)")
def test_gr302_implicit_transfer_detected():
    x = jax.block_until_ready(jnp.arange(16))
    with pytest.raises(guards.GuardViolation, match="GR302"):
        with guards.steady_state():
            np.asarray(x)                 # implicit device->host sync


def test_gr302_rebadges_transfer_errors():
    """The guard re-badges jax's transfer error as GR302 (simulated here so
    the path is covered on the CPU container too)."""
    with pytest.raises(guards.GuardViolation, match="GR302"):
        with guards.steady_state():
            raise RuntimeError(
                "Disallowed device-to-host transfer: int32[16]")


def test_guard_passes_through_unrelated_errors():
    with pytest.raises(ValueError, match="boom"):
        with guards.steady_state():
            raise ValueError("boom")


def test_gr302_explicit_device_get_allowed():
    x = jax.block_until_ready(jnp.arange(16))
    with guards.steady_state():
        out = jax.device_get(x)           # the sanctioned explicit retrieval
    np.testing.assert_array_equal(out, np.arange(16))


def test_guard_explicit_counters():
    calls = {"n": 0}
    with pytest.raises(guards.GuardViolation, match="GR301"):
        with guards.steady_state(counters={"c": lambda: calls["n"]},
                                 transfers=False):
            calls["n"] += 1


# --- CLI -------------------------------------------------------------------

def test_cli_clean_on_repo_tree(capsys):
    rc = cli.main([os.path.join(REPO, "src"),
                   os.path.join(REPO, "tests"),
                   os.path.join(REPO, "benchmarks")])
    out = capsys.readouterr().out
    assert rc == 0, out
    assert "0 finding(s)" in out


def test_cli_flags_violation_file(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text(
        "import jax\nimport numpy as np\n"
        "@jax.jit\n"
        "def step(x):\n"
        "    return np.asarray(x)\n")
    rc = cli.main([str(bad)])
    out = capsys.readouterr().out
    assert rc == 1
    assert "PL101" in out and "bad.py:5" in out


def test_cli_json_output(tmp_path, capsys):
    import json
    bad = tmp_path / "bad.py"
    bad.write_text("import numpy as np\ndef f(a=[]):\n    return np.int64\n")
    rc = cli.main([str(bad), "--json"])
    payload = json.loads(capsys.readouterr().out)
    # tmp_path is outside SRC scope: SRC-scoped PL106/PL109 must NOT fire
    assert rc == 0 and payload["count"] == 0


def test_cli_list_rules(capsys):
    rc = cli.main(["--list-rules"])
    out = capsys.readouterr().out
    assert rc == 0
    for rid in ("PL101", "PC204"):
        assert rid in out


def test_cli_usage_error(capsys):
    assert cli.main([]) == 2
