"""Interpret-mode reference-twin tests for every Pallas kernel variant.

Driven by pallint's PC205 contract: every function containing a
``pl.pallas_call`` must have an interpret-mode twin validated against the
pure oracle — this file provides exactly those twins, at the edge shapes the
BlockSpec contracts are most fragile on (Q or R not tile-divisible,
single-tile, empty-query batch), and closes by asserting the contract
checker's coverage report sees them.
"""
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels import rect_intersect as rk

REPO = os.path.join(os.path.dirname(__file__), "..")

# (Q, R) edge shapes against (tq, tr) = (8, 16): single tile exact,
# non-divisible both sides, sub-tile, and a multi-tile ragged tail.
EDGE_SHAPES = [(8, 16), (5, 13), (1, 1), (17, 33), (24, 16)]
TQ, TR = 8, 16


def _rand(n, seed, scale=2000):
    rng = np.random.default_rng(seed)
    lo = rng.integers(0, scale, (n, 2))
    hi = lo + rng.integers(0, scale // 10 + 1, (n, 2))
    return np.concatenate([lo, hi], axis=1).astype(np.int32)


def _padded(queries, rects):
    qp = ops.pad_rects_to_np(queries, TQ)
    rp = ops.pad_rects_to_np(rects, TR)
    return qp, rp, ops.tile_mbrs_np(qp, TQ), ops.tile_mbrs_np(rp, TR)


def _cover(rects, pad_to=2):
    mbr = np.array([[rects[:, 0].min(), rects[:, 1].min(),
                     rects[:, 2].max(), rects[:, 3].max()]], np.int32)
    empty = np.array([[2**31 - 1, 2**31 - 1, -2**31, -2**31]], np.int32)
    return np.concatenate([mbr, np.tile(empty, (pad_to - 1, 1))])


@pytest.mark.parametrize("q,r", EDGE_SHAPES)
def test_twin_overlap_counts_tiled(q, r):
    queries, rects = _rand(q, seed=q * 11 + r), _rand(r, seed=q + r * 7)
    qp, rp, qmbrs, rmbrs = _padded(queries, rects)
    mask = np.ones(qp.shape[0], np.int32)
    got = np.asarray(rk.overlap_counts_tiled(
        jnp.asarray(qp.T), jnp.asarray(rp.T), jnp.asarray(qmbrs),
        jnp.asarray(rmbrs), jnp.asarray(mask), tq=TQ, tr=TR,
        interpret=True))[:q]
    np.testing.assert_array_equal(got, ref.overlap_counts_np(queries, rects))


@pytest.mark.parametrize("q,r", EDGE_SHAPES)
def test_twin_overlap_counts_tiled_fused(q, r):
    queries, rects = _rand(q, seed=q * 13 + r), _rand(r, seed=q + r * 5)
    qp, rp, qmbrs, rmbrs = _padded(queries, rects)
    got = np.asarray(rk.overlap_counts_tiled_fused(
        jnp.asarray(qp.T), jnp.asarray(rp.T), jnp.asarray(qmbrs),
        jnp.asarray(rmbrs), jnp.asarray(_cover(rects)), tq=TQ, tr=TR,
        interpret=True))[:q]
    np.testing.assert_array_equal(got, ref.overlap_counts_np(queries, rects))


@pytest.mark.parametrize("q,r", EDGE_SHAPES)
def test_twin_overlap_counts_sparse(q, r):
    queries, rects = _rand(q, seed=q * 17 + r), _rand(r, seed=q + r * 3)
    qp, rp, qmbrs, rmbrs = _padded(queries, rects)
    mask = np.ones(qp.shape[0], np.int32)
    nactive, tile_ids = ops.build_active_tiles(qmbrs, rmbrs)
    got = np.asarray(rk.overlap_counts_sparse(
        jnp.asarray(qp.T), jnp.asarray(rp.T), jnp.asarray(mask),
        jnp.asarray(nactive), jnp.asarray(tile_ids), tq=TQ, tr=TR,
        interpret=True))[:q]
    np.testing.assert_array_equal(got, ref.overlap_counts_np(queries, rects))


@pytest.mark.parametrize("q,r", EDGE_SHAPES)
def test_twin_overlap_counts_sparse_fused(q, r):
    queries, rects = _rand(q, seed=q * 19 + r), _rand(r, seed=q + r * 2)
    qp, rp, qmbrs, rmbrs = _padded(queries, rects)
    cover = _cover(rects)
    nactive, tile_ids = ops.build_active_tiles_device(
        jnp.asarray(qmbrs), jnp.asarray(rmbrs), jnp.asarray(cover))
    got = np.asarray(rk.overlap_counts_sparse_fused(
        jnp.asarray(qp.T), jnp.asarray(rp.T), jnp.asarray(cover),
        nactive, tile_ids, tq=TQ, tr=TR, interpret=True))[:q]
    np.testing.assert_array_equal(got, ref.overlap_counts_np(queries, rects))


@pytest.mark.parametrize("impl", ["pallas", "sparse", "xla"])
def test_empty_query_batch(impl):
    """Q == 0 (serving idle tick): every impl returns an empty count vector
    instead of tripping the zero-extent grid."""
    rects = _rand(32, seed=42)
    out = np.asarray(ops.overlap_counts(
        jnp.zeros((0, 4), jnp.int32), jnp.asarray(rects), impl=impl,
        tq=TQ, tr=TR))
    assert out.shape == (0,) and out.dtype == np.int32
    rp = ops.pad_rects_to_np(rects, TR)
    out_f = np.asarray(ops.overlap_counts_fused(
        jnp.zeros((0, 4), jnp.int32), jnp.asarray(rp.T),
        jnp.asarray(ops.tile_mbrs_np(rp, TR)), jnp.asarray(_cover(rects)),
        impl=impl, tq=TQ, tr=TR))
    assert out_f.shape == (0,) and out_f.dtype == np.int32


def test_divisibility_contract_enforced():
    """The sparse wrappers now assert tile divisibility (pallint PC204)
    instead of silently truncating a ragged operand."""
    queries, rects = _rand(TQ, seed=1), _rand(TR + 3, seed=2)  # ragged R
    mask = np.ones(TQ, np.int32)
    nactive = np.zeros(1, np.int32)
    tile_ids = np.zeros((1, 1), np.int32)
    with pytest.raises(AssertionError):
        rk.overlap_counts_sparse(
            jnp.asarray(queries.T), jnp.asarray(rects.T), jnp.asarray(mask),
            jnp.asarray(nactive), jnp.asarray(tile_ids), tq=TQ, tr=TR,
            interpret=True)


def test_contract_checker_sees_full_coverage():
    """PC205 drives this file: the static coverage report must show every
    kernel wrapper in src/ referenced from the test suite."""
    from repro.analysis.pallint import contracts

    report = contracts.coverage_report(
        [os.path.join(REPO, "src")], [os.path.join(REPO, "tests")])
    names = {w["name"] for w in report["kernel_wrappers"]}
    assert {"overlap_counts_tiled", "overlap_counts_tiled_fused",
            "overlap_counts_sparse",
            "overlap_counts_sparse_fused"} <= names
    assert report["missing"] == [], report["missing"]
