"""Interpret-mode reference-twin tests for every Pallas kernel variant.

Driven by pallint's PC205 contract: every function containing a
``pl.pallas_call`` must have an interpret-mode twin validated against the
pure oracle — this file provides exactly those twins, at the edge shapes the
BlockSpec contracts are most fragile on (Q or R not tile-divisible,
single-tile, empty-query batch), and closes by asserting the contract
checker's coverage report sees them.
"""
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import aggregate as ka
from repro.kernels import knn as kk
from repro.kernels import materialize as km
from repro.kernels import ops, ref
from repro.kernels import rect_intersect as rk
from repro.query import oracle as qoracle

REPO = os.path.join(os.path.dirname(__file__), "..")
INT32_MAX = 2**31 - 1

# (Q, R) edge shapes against (tq, tr) = (8, 16): single tile exact,
# non-divisible both sides, sub-tile, and a multi-tile ragged tail.
EDGE_SHAPES = [(8, 16), (5, 13), (1, 1), (17, 33), (24, 16)]
TQ, TR = 8, 16


def _rand(n, seed, scale=2000):
    rng = np.random.default_rng(seed)
    lo = rng.integers(0, scale, (n, 2))
    hi = lo + rng.integers(0, scale // 10 + 1, (n, 2))
    return np.concatenate([lo, hi], axis=1).astype(np.int32)


def _padded(queries, rects):
    qp = ops.pad_rects_to_np(queries, TQ)
    rp = ops.pad_rects_to_np(rects, TR)
    return qp, rp, ops.tile_mbrs_np(qp, TQ), ops.tile_mbrs_np(rp, TR)


def _cover(rects, pad_to=2):
    mbr = np.array([[rects[:, 0].min(), rects[:, 1].min(),
                     rects[:, 2].max(), rects[:, 3].max()]], np.int32)
    empty = np.array([[2**31 - 1, 2**31 - 1, -2**31, -2**31]], np.int32)
    return np.concatenate([mbr, np.tile(empty, (pad_to - 1, 1))])


@pytest.mark.parametrize("q,r", EDGE_SHAPES)
def test_twin_overlap_counts_tiled(q, r):
    queries, rects = _rand(q, seed=q * 11 + r), _rand(r, seed=q + r * 7)
    qp, rp, qmbrs, rmbrs = _padded(queries, rects)
    mask = np.ones(qp.shape[0], np.int32)
    got = np.asarray(rk.overlap_counts_tiled(
        jnp.asarray(qp.T), jnp.asarray(rp.T), jnp.asarray(qmbrs),
        jnp.asarray(rmbrs), jnp.asarray(mask), tq=TQ, tr=TR,
        interpret=True))[:q]
    np.testing.assert_array_equal(got, ref.overlap_counts_np(queries, rects))


@pytest.mark.parametrize("q,r", EDGE_SHAPES)
def test_twin_overlap_counts_tiled_fused(q, r):
    queries, rects = _rand(q, seed=q * 13 + r), _rand(r, seed=q + r * 5)
    qp, rp, qmbrs, rmbrs = _padded(queries, rects)
    got = np.asarray(rk.overlap_counts_tiled_fused(
        jnp.asarray(qp.T), jnp.asarray(rp.T), jnp.asarray(qmbrs),
        jnp.asarray(rmbrs), jnp.asarray(_cover(rects)), tq=TQ, tr=TR,
        interpret=True))[:q]
    np.testing.assert_array_equal(got, ref.overlap_counts_np(queries, rects))


@pytest.mark.parametrize("q,r", EDGE_SHAPES)
def test_twin_overlap_counts_sparse(q, r):
    queries, rects = _rand(q, seed=q * 17 + r), _rand(r, seed=q + r * 3)
    qp, rp, qmbrs, rmbrs = _padded(queries, rects)
    mask = np.ones(qp.shape[0], np.int32)
    nactive, tile_ids = ops.build_active_tiles(qmbrs, rmbrs)
    got = np.asarray(rk.overlap_counts_sparse(
        jnp.asarray(qp.T), jnp.asarray(rp.T), jnp.asarray(mask),
        jnp.asarray(nactive), jnp.asarray(tile_ids), tq=TQ, tr=TR,
        interpret=True))[:q]
    np.testing.assert_array_equal(got, ref.overlap_counts_np(queries, rects))


@pytest.mark.parametrize("q,r", EDGE_SHAPES)
def test_twin_overlap_counts_sparse_fused(q, r):
    queries, rects = _rand(q, seed=q * 19 + r), _rand(r, seed=q + r * 2)
    qp, rp, qmbrs, rmbrs = _padded(queries, rects)
    cover = _cover(rects)
    nactive, tile_ids = ops.build_active_tiles_device(
        jnp.asarray(qmbrs), jnp.asarray(rmbrs), jnp.asarray(cover))
    got = np.asarray(rk.overlap_counts_sparse_fused(
        jnp.asarray(qp.T), jnp.asarray(rp.T), jnp.asarray(cover),
        nactive, tile_ids, tq=TQ, tr=TR, interpret=True))[:q]
    np.testing.assert_array_equal(got, ref.overlap_counts_np(queries, rects))


# --- repro.query kernel twins (materialize / knn / aggregate) --------------

def _placed(rects):
    """Single-device 'placement': EMPTY-padded rects + aligned source IDs."""
    rp = ops.pad_rects_to_np(rects, TR)
    ids = np.full(rp.shape[0], -1, np.int32)
    ids[: rects.shape[0]] = np.arange(rects.shape[0], dtype=np.int32)
    return rp, ids, ops.tile_mbrs_np(rp, TR)


def _points(n, seed, scale=2000):
    rng = np.random.default_rng(seed)
    return rng.integers(0, scale, (n, 2)).astype(np.int32)


@pytest.mark.parametrize("q,r", EDGE_SHAPES)
def test_twin_materialize_ids_tiled(q, r):
    """Pass-2 ID scatter twin: slots bit-equal to the placed-order oracle,
    including overflow saturation at a tight Kcap."""
    queries, rects = _rand(q, seed=q * 23 + r), _rand(r, seed=q + r * 9)
    rp, ids, rmbrs = _placed(rects)
    qp = ops.pad_rects_to_np(queries, TQ)
    kcap = 4    # tight: random overlaps overflow it at the larger shapes
    slots, counts = km.materialize_ids_tiled(
        jnp.asarray(qp.T), jnp.asarray(rp.T), jnp.asarray(ids),
        jnp.asarray(ops.tile_mbrs_np(qp, TQ)), jnp.asarray(rmbrs),
        jnp.asarray(_cover(rects)), jnp.zeros(qp.shape[0], jnp.int32),
        kcap=kcap, tq=TQ, tr=TR, interpret=True)
    w_ids, w_cnt, w_over = qoracle.ids_oracle(queries, rp, ids, kcap=kcap)
    np.testing.assert_array_equal(np.asarray(slots)[:q] - 1, w_ids)
    np.testing.assert_array_equal(np.asarray(counts)[:q], w_cnt)
    # saturation: true totals exceed kcap exactly where the oracle says
    assert (np.asarray(counts)[:q] - kcap).clip(min=0).tolist() \
        == w_over.tolist()


@pytest.mark.parametrize("q,r", EDGE_SHAPES)
def test_twin_materialize_radius_tiled(q, r):
    queries, rects = _points(q, seed=q * 29 + r), _rand(r, seed=q + r * 31)
    radii = np.random.default_rng(q * 37 + r).integers(
        0, 500, q).astype(np.int32)
    rp, ids, rmbrs = _placed(rects)
    pp = np.asarray(ops._pad_points(jnp.asarray(queries), TQ))
    radp = np.full(pp.shape[0], -1, np.int32)
    radp[:q] = radii
    slots, counts = km.materialize_radius_tiled(
        jnp.asarray(pp.T), jnp.asarray(radp), jnp.asarray(rp.T),
        jnp.asarray(ids), ops._point_tile_mbrs(jnp.asarray(pp.T), TQ),
        jnp.asarray(rmbrs), jnp.zeros(pp.shape[0], jnp.int32),
        kcap=8, tq=TQ, tr=TR, interpret=True)
    w_ids, w_cnt, _ = qoracle.radius_oracle(queries, radii, rp, ids, kcap=8)
    np.testing.assert_array_equal(np.asarray(slots)[:q] - 1, w_ids)
    np.testing.assert_array_equal(np.asarray(counts)[:q], w_cnt)


def test_twin_radius_boundary_touching():
    """Closed-ball contract: a point exactly r away from the rect edge is IN
    (d2 == r*r bit-equal in f32), one unit farther is OUT."""
    rects = np.array([[100, 100, 200, 200]], np.int32)
    rp, ids, rmbrs = _placed(rects)
    r = 75
    pts = np.array([[100 - r, 150],        # exactly on the ball boundary
                    [100 - r - 1, 150],    # one unit outside
                    [100, 100 - r]], np.int32)
    radii = np.full(3, r, np.int32)
    pp = np.asarray(ops._pad_points(jnp.asarray(pts), TQ))
    radp = np.full(pp.shape[0], -1, np.int32)
    radp[:3] = radii
    slots, counts = km.materialize_radius_tiled(
        jnp.asarray(pp.T), jnp.asarray(radp), jnp.asarray(rp.T),
        jnp.asarray(ids), ops._point_tile_mbrs(jnp.asarray(pp.T), TQ),
        jnp.asarray(rmbrs), jnp.zeros(pp.shape[0], jnp.int32),
        kcap=4, tq=TQ, tr=TR, interpret=True)
    np.testing.assert_array_equal(np.asarray(counts)[:3], [1, 0, 1])
    w_ids, w_cnt, _ = qoracle.radius_oracle(pts, radii, rp, ids, kcap=4)
    np.testing.assert_array_equal(np.asarray(slots)[:3] - 1, w_ids)
    np.testing.assert_array_equal(np.asarray(counts)[:3], w_cnt)


@pytest.mark.parametrize("q,r", EDGE_SHAPES)
def test_twin_knn_tiled(q, r):
    pts, rects = _points(q, seed=q * 41 + r), _rand(r, seed=q + r * 43)
    rp, ids, rmbrs = _placed(rects)
    k = 4
    pp = np.asarray(ops._pad_points(jnp.asarray(pts), TQ))
    dists, got_ids = kk.knn_tiled(
        jnp.asarray(pp.T), jnp.asarray(rp.T), jnp.asarray(ids),
        ops._point_tile_mbrs(jnp.asarray(pp.T), TQ), jnp.asarray(rmbrs),
        k=k, tq=TQ, tr=TR, interpret=True)
    w_d, w_i = qoracle.knn_oracle(pts, rp, ids, k=k)
    gi = np.asarray(got_ids)[:q]
    np.testing.assert_array_equal(np.where(gi == INT32_MAX, -1, gi), w_i)
    np.testing.assert_array_equal(np.asarray(dists)[:q], w_d)


def test_twin_knn_ties_broken_by_id():
    """Identical rects at identical distance: the k slots fill in ascending
    source-ID order, bit-equal with the oracle's (d2, id) lexsort."""
    rect = [100, 100, 120, 120]
    rects = np.array([rect] * 5, np.int32)
    rp, ids, rmbrs = _placed(rects)
    pts = np.array([[50, 110], [110, 110]], np.int32)
    pp = np.asarray(ops._pad_points(jnp.asarray(pts), TQ))
    dists, got_ids = kk.knn_tiled(
        jnp.asarray(pp.T), jnp.asarray(rp.T), jnp.asarray(ids),
        ops._point_tile_mbrs(jnp.asarray(pp.T), TQ), jnp.asarray(rmbrs),
        k=3, tq=TQ, tr=TR, interpret=True)
    np.testing.assert_array_equal(np.asarray(got_ids)[:2],
                                  [[0, 1, 2], [0, 1, 2]])
    w_d, w_i = qoracle.knn_oracle(pts, rp, ids, k=3)
    np.testing.assert_array_equal(np.asarray(got_ids)[:2], w_i)
    np.testing.assert_array_equal(np.asarray(dists)[:2], w_d)


@pytest.mark.parametrize("q,r", EDGE_SHAPES)
def test_twin_aggregate_tiled(q, r):
    """Counts and bbox bit-equal; f32 on-fabric sums within the documented
    tolerance of the float64 oracle."""
    queries, rects = _rand(q, seed=q * 47 + r), _rand(r, seed=q + r * 53)
    rp, _, rmbrs = _placed(rects)
    qp = ops.pad_rects_to_np(queries, TQ)
    counts, sums, bbox = ka.aggregate_tiled(
        jnp.asarray(qp.T), jnp.asarray(rp.T),
        jnp.asarray(ops.tile_mbrs_np(qp, TQ)), jnp.asarray(rmbrs),
        jnp.asarray(_cover(rects)), tq=TQ, tr=TR, interpret=True)
    w_cnt, w_sums, w_bbox = qoracle.aggregate_oracle(queries, rp)
    np.testing.assert_array_equal(np.asarray(counts)[:q], w_cnt)
    np.testing.assert_array_equal(np.asarray(bbox).T[:q], w_bbox)
    np.testing.assert_allclose(np.asarray(sums).T[:q], w_sums,
                               rtol=qoracle.AGG_RTOL, atol=qoracle.AGG_ATOL)


@pytest.mark.parametrize("impl", ["pallas", "sparse", "xla"])
def test_empty_query_batch(impl):
    """Q == 0 (serving idle tick): every impl returns an empty count vector
    instead of tripping the zero-extent grid."""
    rects = _rand(32, seed=42)
    out = np.asarray(ops.overlap_counts(
        jnp.zeros((0, 4), jnp.int32), jnp.asarray(rects), impl=impl,
        tq=TQ, tr=TR))
    assert out.shape == (0,) and out.dtype == np.int32
    rp = ops.pad_rects_to_np(rects, TR)
    out_f = np.asarray(ops.overlap_counts_fused(
        jnp.zeros((0, 4), jnp.int32), jnp.asarray(rp.T),
        jnp.asarray(ops.tile_mbrs_np(rp, TR)), jnp.asarray(_cover(rects)),
        impl=impl, tq=TQ, tr=TR))
    assert out_f.shape == (0,) and out_f.dtype == np.int32


def test_divisibility_contract_enforced():
    """The sparse wrappers now assert tile divisibility (pallint PC204)
    instead of silently truncating a ragged operand."""
    queries, rects = _rand(TQ, seed=1), _rand(TR + 3, seed=2)  # ragged R
    mask = np.ones(TQ, np.int32)
    nactive = np.zeros(1, np.int32)
    tile_ids = np.zeros((1, 1), np.int32)
    with pytest.raises(AssertionError):
        rk.overlap_counts_sparse(
            jnp.asarray(queries.T), jnp.asarray(rects.T), jnp.asarray(mask),
            jnp.asarray(nactive), jnp.asarray(tile_ids), tq=TQ, tr=TR,
            interpret=True)


def test_contract_checker_sees_full_coverage():
    """PC205 drives this file: the static coverage report must show every
    kernel wrapper in src/ referenced from the test suite."""
    from repro.analysis.pallint import contracts

    report = contracts.coverage_report(
        [os.path.join(REPO, "src")], [os.path.join(REPO, "tests")])
    names = {w["name"] for w in report["kernel_wrappers"]}
    assert {"overlap_counts_tiled", "overlap_counts_tiled_fused",
            "overlap_counts_sparse", "overlap_counts_sparse_fused",
            "materialize_ids_tiled", "materialize_radius_tiled",
            "knn_tiled", "aggregate_tiled"} <= names
    assert report["missing"] == [], report["missing"]
