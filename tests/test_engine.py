"""Engine correctness: broadcast + subtree engines vs oracle.

In-process tests use a 1-device mesh (the main pytest process must keep a
single CPU device per the dry-run isolation rule); multi-device SPMD tests
run in subprocesses with 8 fake host devices.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from repro import compat

from repro.core import engine as beng
from repro.core import rtree, subtree
from repro.data import spider, datasets
from repro.kernels import ref

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _mesh1():
    return compat.make_mesh((1, 1), ("data", "model"))


def test_broadcast_engine_single_device():
    rects = spider.uniform(5000, seed=1, max_size=0.01)
    queries = datasets.make_queries(rects, 0.02, seed=2)
    tree = rtree.build_str_3level(rects, leaf_capacity=32, fanout=8)
    eng = beng.BroadcastEngine(tree, _mesh1(), batch_size=64)
    got = eng.query(queries)
    want = ref.overlap_counts_np(queries, rects)
    np.testing.assert_array_equal(got, want)


def test_subtree_engine_single_device():
    rects = spider.gaussian(3000, seed=3, max_size=0.01)
    queries = datasets.make_queries(rects, 0.02, seed=4)
    eng = subtree.SubtreeEngine(rects, _mesh1(), leaf_capacity=64,
                                batch_size=32)
    got = eng.query(queries)
    want = ref.overlap_counts_np(queries, rects)
    np.testing.assert_array_equal(got, want)


def test_shard_layout_contiguity():
    rects = spider.uniform(2000, seed=5)
    tree = rtree.build_str_3level(rects, leaf_capacity=16, fanout=8)
    layout = beng.shard_tree(tree, 8)
    assert layout.leaf_rects_flat.shape[0] == 8 * layout.rects_per_device
    # every device's cover list is non-trivially bounded (paper: <= 4-ish)
    assert 1 <= layout.kmax <= tree.num_l1
    # reconstructing the rect multiset from shards preserves the dataset
    valid = layout.leaf_rects_flat[layout.leaf_rects_flat[:, 0]
                                   <= layout.leaf_rects_flat[:, 2]]
    assert valid.shape[0] == 2000


def test_transfer_model_broadcast_beats_subtree():
    """Paper Table III / Fig 7: the subtree baseline moves far more bytes."""
    rects = spider.uniform(20_000, seed=6)
    tree = rtree.build_str_3level(rects, *rtree.choose_parameters(20_000, 8))
    mesh = _mesh1()
    b = beng.BroadcastEngine(tree, mesh, batch_size=1000)
    s = subtree.SubtreeEngine(rects, mesh, leaf_capacity=64, batch_size=1000)
    nq = 5000
    bt = b.transfer_stats(nq)
    st_ = s.transfer_stats(nq)
    broadcast_total = (bt["header_broadcast_bytes"] + bt["leaf_scatter_bytes"]
                       + bt["query_broadcast_bytes"])
    assert st_["total_scatter_bytes"] > broadcast_total


_MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from repro import compat
    from repro.core import engine as beng
    from repro.core import rtree, subtree
    from repro.data import spider, datasets
    from repro.kernels import ref

    assert jax.device_count() == 8
    mesh = compat.make_mesh((4, 2), ("data", "model"))
    rects = spider.diagonal(8000, seed=11, max_size=0.01)
    queries = datasets.make_queries(rects, 0.03, seed=12)
    want = ref.overlap_counts_np(queries, rects)

    tree = rtree.build_str_3level(rects, leaf_capacity=16, fanout=8)
    eng = beng.BroadcastEngine(tree, mesh, batch_size=128)
    got = eng.query(queries)
    np.testing.assert_array_equal(got, want)

    s_eng = subtree.SubtreeEngine(rects, mesh, leaf_capacity=64,
                                  batch_size=128)
    got_s = s_eng.query(queries)
    np.testing.assert_array_equal(got_s, want)

    # Pallas path under shard_map (interpret mode) on a small slice
    eng_k = beng.BroadcastEngine(tree, mesh, impl="pallas", tq=16, tr=64,
                                 batch_size=64)
    got_k = eng_k.query(queries[:64])
    np.testing.assert_array_equal(got_k, want[:64])
    print("MULTIDEV_OK")
""")


@pytest.mark.slow
def test_engines_multidevice_8():
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO_SRC
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", _MULTIDEV_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "MULTIDEV_OK" in r.stdout


def test_sort_queries_exact():
    """§Perf S2: Morton-sorted batching is an internal reordering — counts
    must be bit-identical to the unsorted engine and the oracle."""
    from repro.core.engine import morton_order
    rects = spider.gaussian(20_000, seed=21, max_size=0.01)
    queries = datasets.make_queries(rects, 0.05, seed=22)
    tree = rtree.build_str_3level(rects, leaf_capacity=32, fanout=8)
    eng = beng.BroadcastEngine(tree, _mesh1(), batch_size=512,
                               sort_queries=True)
    got = eng.query(queries)
    want = ref.overlap_counts_np(queries, rects)
    np.testing.assert_array_equal(got, want)
    # the ordering really is a permutation
    order = morton_order(queries)
    assert sorted(order.tolist()) == list(range(len(queries)))


def test_steady_state_zero_host_metadata(monkeypatch, pallint_steady_state):
    """Acceptance: the steady-state batch loop does zero per-batch host-side
    metadata construction.  Trace-count freezing and implicit-transfer
    detection come from the shared pallint guard (GR301/GR302); the
    monkeypatched builders additionally prove the host metadata path
    (tile_mbrs over leaf arrays / Python build_active_tiles) is never hit."""
    from repro.kernels import ops as kops

    rects = spider.uniform(4000, seed=31, max_size=0.01)
    queries = datasets.make_queries(rects, 0.5, seed=32)   # 2000 queries
    tree = rtree.build_str_3level(rects, leaf_capacity=32, fanout=8)
    eng = beng.BroadcastEngine(tree, _mesh1(), batch_size=128)

    eng.query(queries[:128])               # warmup: compile once
    assert eng.trace_count >= 1

    calls = {"tile_mbrs": 0, "build_active_tiles": 0}
    real_tile_mbrs = kops.tile_mbrs
    real_bat = kops.build_active_tiles

    def counting_tile_mbrs(*a, **k):
        calls["tile_mbrs"] += 1
        return real_tile_mbrs(*a, **k)

    def counting_bat(*a, **k):
        calls["build_active_tiles"] += 1
        return real_bat(*a, **k)

    monkeypatch.setattr(kops, "tile_mbrs", counting_tile_mbrs)
    monkeypatch.setattr(kops, "build_active_tiles", counting_bat)

    with pallint_steady_state(
            entrypoints={"broadcast_step": eng._step},
            counters={"broadcast_trace": lambda: eng.trace_count},
            where="BroadcastEngine.query"):
        got = eng.query(queries)           # 16 steady-state batches
    want = ref.overlap_counts_np(queries, rects)
    np.testing.assert_array_equal(got, want)
    assert calls == {"tile_mbrs": 0, "build_active_tiles": 0}, calls


def test_subtree_steady_state_guarded(pallint_steady_state):
    """The subtree baseline's steady state is held to the same doctrine:
    no retrace, no implicit device->host transfer after warmup."""
    rects = spider.gaussian(3000, seed=33, max_size=0.01)
    queries = datasets.make_queries(rects, 0.2, seed=34)
    eng = subtree.SubtreeEngine(rects, _mesh1(), leaf_capacity=64,
                                batch_size=64)
    eng.query(queries[:64])                # warmup
    with pallint_steady_state(
            entrypoints={"subtree_step": eng._step},
            counters={"subtree_trace": lambda: eng.trace_count},
            where="SubtreeEngine.query"):
        got = eng.query(queries)
    want = ref.overlap_counts_np(queries, rects)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("impl", ["pallas", "sparse", "xla"])
def test_broadcast_engine_impl_sweep(impl):
    """All three kernel impls must be exact through the full engine path —
    fused Phase-1, cached tile metadata, streaming loop, tail-batch pad."""
    rects = spider.uniform(900, seed=33, max_size=0.02)
    queries = datasets.make_queries(rects, 0.2, seed=34)   # 180 queries
    tree = rtree.build_str_3level(rects, leaf_capacity=16, fanout=4)
    eng = beng.BroadcastEngine(tree, _mesh1(), impl=impl, tq=16, tr=64,
                               batch_size=50)              # uneven tail
    got = eng.query(queries)
    want = ref.overlap_counts_np(queries, rects)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("impl", ["sparse", "xla"])
def test_subtree_engine_impl_sweep(impl):
    rects = spider.gaussian(800, seed=35, max_size=0.02)
    queries = datasets.make_queries(rects, 0.2, seed=36)
    eng = subtree.SubtreeEngine(rects, _mesh1(), leaf_capacity=64,
                                impl=impl, tq=16, tr=64, batch_size=48)
    got = eng.query(queries)
    want = ref.overlap_counts_np(queries, rects)
    np.testing.assert_array_equal(got, want)


def test_shard_tree_metadata_cache():
    """Placement-time cache: per-device tile MBRs equal the kernel helper
    applied to each device slice, and occupancy accounts for every rect."""
    from repro.kernels import ops as kops
    import jax.numpy as jnp

    rects = spider.uniform(3000, seed=37)
    tree = rtree.build_str_3level(rects, leaf_capacity=16, fanout=8)
    layout = beng.shard_tree(tree, 4, tile=64)
    d, rp = 4, layout.rects_per_device
    assert rp % 64 == 0
    per_dev = layout.leaf_rects_flat.reshape(d, rp, 4)
    for dev in range(d):
        want = np.asarray(kops.tile_mbrs(jnp.asarray(per_dev[dev]), 64))
        np.testing.assert_array_equal(layout.rect_tile_mbrs[dev], want)
    assert int(layout.tile_occupancy.sum()) == 3000
    assert layout.metadata_bytes > 0


def test_morton_order_wide_coordinates():
    """Satellite: 21-bit interleave — clusters separated by ~2^30 must not
    collapse into one Z-code bucket (the old 10-bit code saw identical codes
    for everything beyond 2^22 with the default shift)."""
    from repro.core.engine import morton_order
    rng = np.random.default_rng(38)

    def cluster(offset, n=64):
        lo = rng.integers(0, 1 << 20, (n, 2)) + offset
        return np.concatenate([lo, lo + 10], axis=1).astype(np.int64)

    a = cluster(0)
    b = cluster(1 << 30)
    queries = np.concatenate([a, b])[rng.permutation(128)]
    order = morton_order(queries.astype(np.int32))
    is_b = (queries[order][:, 0] >= (1 << 29)).astype(int)
    # a correct wide Z-code sorts one cluster entirely before the other
    assert (np.diff(is_b) >= 0).all() or (np.diff(is_b) <= 0).all()
    assert sorted(order.tolist()) == list(range(128))


def test_query_edge_sizes():
    """Zero/one-query calls (serving edge): no crash, exact, empty-in →
    empty-out even with Morton sorting enabled."""
    rects = spider.gaussian(1000, seed=41, max_size=0.02)
    tree = rtree.build_str_3level(rects, leaf_capacity=16, fanout=4)
    eng = beng.BroadcastEngine(tree, _mesh1(), batch_size=64,
                               sort_queries=True)
    queries = datasets.make_queries(rects, 0.1, seed=42)
    np.testing.assert_array_equal(
        eng.query(queries[:1]), ref.overlap_counts_np(queries[:1], rects))
    out = eng.query(np.zeros((0, 4), np.int32))
    assert out.shape == (0,) and out.dtype == np.int32
