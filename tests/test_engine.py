"""Engine correctness: broadcast + subtree engines vs oracle.

In-process tests use a 1-device mesh (the main pytest process must keep a
single CPU device per the dry-run isolation rule); multi-device SPMD tests
run in subprocesses with 8 fake host devices.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from repro.core import engine as beng
from repro.core import rtree, subtree
from repro.data import spider, datasets
from repro.kernels import ref

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _mesh1():
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def test_broadcast_engine_single_device():
    rects = spider.uniform(5000, seed=1, max_size=0.01)
    queries = datasets.make_queries(rects, 0.02, seed=2)
    tree = rtree.build_str_3level(rects, leaf_capacity=32, fanout=8)
    eng = beng.BroadcastEngine(tree, _mesh1(), batch_size=64)
    got = eng.query(queries)
    want = ref.overlap_counts_np(queries, rects)
    np.testing.assert_array_equal(got, want)


def test_subtree_engine_single_device():
    rects = spider.gaussian(3000, seed=3, max_size=0.01)
    queries = datasets.make_queries(rects, 0.02, seed=4)
    eng = subtree.SubtreeEngine(rects, _mesh1(), leaf_capacity=64,
                                batch_size=32)
    got = eng.query(queries)
    want = ref.overlap_counts_np(queries, rects)
    np.testing.assert_array_equal(got, want)


def test_shard_layout_contiguity():
    rects = spider.uniform(2000, seed=5)
    tree = rtree.build_str_3level(rects, leaf_capacity=16, fanout=8)
    layout = beng.shard_tree(tree, 8)
    assert layout.leaf_rects_flat.shape[0] == 8 * layout.rects_per_device
    # every device's cover list is non-trivially bounded (paper: <= 4-ish)
    assert 1 <= layout.kmax <= tree.num_l1
    # reconstructing the rect multiset from shards preserves the dataset
    valid = layout.leaf_rects_flat[layout.leaf_rects_flat[:, 0]
                                   <= layout.leaf_rects_flat[:, 2]]
    assert valid.shape[0] == 2000


def test_transfer_model_broadcast_beats_subtree():
    """Paper Table III / Fig 7: the subtree baseline moves far more bytes."""
    rects = spider.uniform(20_000, seed=6)
    tree = rtree.build_str_3level(rects, *rtree.choose_parameters(20_000, 8))
    mesh = _mesh1()
    b = beng.BroadcastEngine(tree, mesh, batch_size=1000)
    s = subtree.SubtreeEngine(rects, mesh, leaf_capacity=64, batch_size=1000)
    nq = 5000
    bt = b.transfer_stats(nq)
    st_ = s.transfer_stats(nq)
    broadcast_total = (bt["header_broadcast_bytes"] + bt["leaf_scatter_bytes"]
                       + bt["query_broadcast_bytes"])
    assert st_["total_scatter_bytes"] > broadcast_total


_MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from repro.core import engine as beng
    from repro.core import rtree, subtree
    from repro.data import spider, datasets
    from repro.kernels import ref

    assert jax.device_count() == 8
    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    rects = spider.diagonal(8000, seed=11, max_size=0.01)
    queries = datasets.make_queries(rects, 0.03, seed=12)
    want = ref.overlap_counts_np(queries, rects)

    tree = rtree.build_str_3level(rects, leaf_capacity=16, fanout=8)
    eng = beng.BroadcastEngine(tree, mesh, batch_size=128)
    got = eng.query(queries)
    np.testing.assert_array_equal(got, want)

    s_eng = subtree.SubtreeEngine(rects, mesh, leaf_capacity=64,
                                  batch_size=128)
    got_s = s_eng.query(queries)
    np.testing.assert_array_equal(got_s, want)

    # Pallas path under shard_map (interpret mode) on a small slice
    eng_k = beng.BroadcastEngine(tree, mesh, impl="pallas", tq=16, tr=64,
                                 batch_size=64)
    got_k = eng_k.query(queries[:64])
    np.testing.assert_array_equal(got_k, want[:64])
    print("MULTIDEV_OK")
""")


@pytest.mark.slow
def test_engines_multidevice_8():
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO_SRC
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", _MULTIDEV_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "MULTIDEV_OK" in r.stdout


def test_sort_queries_exact():
    """§Perf S2: Morton-sorted batching is an internal reordering — counts
    must be bit-identical to the unsorted engine and the oracle."""
    from repro.core.engine import morton_order
    rects = spider.gaussian(20_000, seed=21, max_size=0.01)
    queries = datasets.make_queries(rects, 0.05, seed=22)
    tree = rtree.build_str_3level(rects, leaf_capacity=32, fanout=8)
    eng = beng.BroadcastEngine(tree, _mesh1(), batch_size=512,
                               sort_queries=True)
    got = eng.query(queries)
    want = ref.overlap_counts_np(queries, rects)
    np.testing.assert_array_equal(got, want)
    # the ordering really is a permutation
    order = morton_order(queries)
    assert sorted(order.tolist()) == list(range(len(queries)))
