"""Multi-replica router: no-fault contract + the ISSUE's router invariants.

Covers the no-fault/deterministic side: bit-equality with the single-replica
engine, strict admission, health probes, routing metrics aggregation, hedging
under a straggler, poisoned-replica ejection, and the rolling layout swap's
version fence (machine-checked: every submit lands on an ACTIVE replica, and
no server executes batches from more than one layout version).

The concurrent crash+straggler+swap scenario lives in
``tests/test_chaos_router.py`` (marker ``chaos_router``, dedicated CI job).
"""
import threading

import numpy as np
import pytest

from repro import compat
from repro.core import engine as beng
from repro.core import rtree
from repro.core.engine import QueryValidationError
from repro.data import datasets, spider
from repro.kernels import ref
from repro.serve import router as router_mod
from repro.serve import spatial_serve
from repro.serve.router import (
    ACTIVE, DRAINING, EJECTED, RETIRED, STATUS_FAILED,
    Replica, ReplicaUnavailableError, RouterConfig, SpatialRouter)
from repro.serve.spatial_serve import STATUS_OK, ServeConfig
from repro.testing import chaos


def _mesh1():
    return compat.make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="module")
def workload():
    rects = spider.uniform(2500, seed=71, max_size=0.02)
    queries = datasets.make_queries(rects, 0.2, seed=72)   # 500 queries
    tree = rtree.build_str_3level(rects, leaf_capacity=32, fanout=8)
    rects2 = spider.uniform(2500, seed=73, max_size=0.02)
    tree2 = rtree.build_str_3level(rects2, leaf_capacity=32, fanout=8)
    return rects, queries, tree, rects2, tree2


def _factory(tree):
    def make():
        return beng.BroadcastEngine(tree, _mesh1(), batch_size=64)
    return make


def _router(tree, *, serve=None, **cfg):
    serve_cfg = dict(batch_size=64, watchdog_s=30.0, crosscheck_every=0)
    serve_cfg.update(serve or {})
    defaults = dict(num_replicas=2, attempt_timeout_s=30.0)
    defaults.update(cfg)
    return SpatialRouter(_factory(tree),
                         config=RouterConfig(**defaults),
                         serve_config=ServeConfig(**serve_cfg))


def _route_all(router, queries, deadline_s=60.0, wait_s=120.0):
    tickets = [router.submit(q, deadline_s=deadline_s) for q in queries]
    assert all(t.wait(wait_s) for t in tickets), "router dropped a request"
    return tickets


# ---------------------------------------------------------------- invariants


def test_bit_equal_to_single_replica_engine(workload):
    """ISSUE invariant 1: under no faults, routed counts are bit-equal to
    one ``BroadcastEngine.query`` call — across both replicas."""
    _, queries, tree, _, _ = workload
    router = _router(tree)
    try:
        want = np.asarray(_factory(tree)().query(queries))
        tickets = _route_all(router, queries)
        assert all(t.status == STATUS_OK for t in tickets)
        got = np.array([t.count for t in tickets], dtype=np.int32)
        np.testing.assert_array_equal(got, want)
        used = {t.replica for t in tickets}
        assert used == {"r0", "r1"}        # both replicas actually served
        assert all(t.layout_version == router.layout_version
                   for t in tickets)
    finally:
        router.stop()


def test_exactly_once_under_crash_failover(workload):
    """ISSUE invariant 3: a persistently crashing replica costs failovers,
    never responses — every ticket completes exactly once, no dupes, no
    drops, all exact."""
    rects, queries, tree, _, _ = workload
    router = _router(tree)
    rc = chaos.ReplicaChaos(
        [chaos.Fault(chaos.REPLICA_CRASH, at_call=0, count=1, period=1)],
        seed=101).install(router.replicas()[0])
    completions = []
    orig_complete = router_mod.RouterTicket._complete

    def counting_complete(self, **fields):
        won = orig_complete(self, **fields)
        if won:
            completions.append(self)
        return won

    try:
        router_mod.RouterTicket._complete = counting_complete
        tickets = _route_all(router, queries[:100])
    finally:
        router_mod.RouterTicket._complete = orig_complete
        router.stop()
    err = rc.describe()
    assert all(t.status == STATUS_OK for t in tickets), err
    got = np.array([t.count for t in tickets], dtype=np.int32)
    np.testing.assert_array_equal(
        got, ref.overlap_counts_np(queries[:100], rects), err_msg=err)
    # exactly-once: each ticket completed once, nothing extra, nothing lost
    assert len(completions) == len(tickets), err
    assert set(id(t) for t in completions) == set(id(t) for t in tickets)
    m = router.metrics()
    assert m["failovers"] > 0 and m["responses_failed"] == 0
    assert all(t.replica == "r1" for t in tickets)


def test_rolling_swap_version_fence(workload):
    """ISSUE invariant 2: during a rolling layout swap, every submit lands
    on an ACTIVE replica and no server ever executes batches from more than
    one layout version — machine-checked at both seams."""
    rects, queries, tree, rects2, tree2 = workload
    submits = []
    orig_submit = Replica.submit

    def logging_submit(self, rect, **kw):
        submits.append((self.name, self.state, self.layout_version))
        # tag the server with its owner's (immutable) version: any server
        # that ever logs two distinct tags executed two layouts
        self.server._version_tag = self.layout_version
        return orig_submit(self, rect, **kw)

    executes = {}
    orig_execute = spatial_serve.SpatialServer._execute

    def logging_execute(self, padded, k, kind="count"):
        executes.setdefault(id(self), set()).add(
            getattr(self, "_version_tag", None))
        return orig_execute(self, padded, k, kind)

    router = _router(tree)
    v1 = router.layout_version
    try:
        Replica.submit = logging_submit
        spatial_serve.SpatialServer._execute = logging_execute

        stop = threading.Event()
        tickets = []

        def traffic():
            i = 0
            while not stop.is_set() and i < 3000:
                tickets.append(
                    router.submit(queries[i % len(queries)], deadline_s=60.0))
                i += 1
                stop.wait(0.005)

        t = threading.Thread(target=traffic)
        t.start()
        try:
            router.swap_layout(_factory(tree2))
        finally:
            stop.set()
            t.join(30.0)
        assert all(t.wait(120.0) for t in tickets)
    finally:
        Replica.submit = orig_submit
        spatial_serve.SpatialServer._execute = orig_execute
        router.stop()

    v2 = router.layout_version
    assert v2 != v1 and router.metrics()["layout_swaps"] == 1
    # fence check 1: every submit hit an ACTIVE replica (no draining/retired
    # replica ever accepted work)
    assert submits and all(state == ACTIVE for _, state, _ in submits)
    # fence check 2: each server executed exactly one layout version —
    # no batch can have mixed versions if no *server* ever saw two
    assert executes and all(len(vs) == 1 for vs in executes.values())
    # zero dropped in-flight: everything admitted before/during the swap
    # completed (ok on whichever version served it; failed never)
    assert all(t.status == STATUS_OK for t in tickets)
    by_version = {t.layout_version for t in tickets}
    assert by_version <= {v1, v2}
    # every answer is exact for the layout that served it
    w1 = ref.overlap_counts_np(
        np.stack([t.rect for t in tickets]), rects)
    w2 = ref.overlap_counts_np(
        np.stack([t.rect for t in tickets]), rects2)
    for i, tk in enumerate(tickets):
        want = w1[i] if tk.layout_version == v1 else w2[i]
        assert tk.count == want, (i, tk.layout_version, tk.count, want)
    # old replicas drained + retired, new pool serves v2 only
    assert all(r.state == RETIRED for r in router._retired)
    assert all(r.layout_version == v2 for r in router.replicas())


# ------------------------------------------------------------------- hedging


def test_hedging_cuts_straggler_tail(workload):
    """A persistent straggler on one replica: hedged p99 must beat unhedged
    p99 by a wide margin (the tail-at-scale contract), with every response
    still exact and the losing duplicate cancelled when possible."""
    rects, queries, tree, _, _ = workload

    def run(hedge):
        router = _router(
            tree, hedge=hedge, hedge_delay_s=0.02,
            serve=dict(watchdog_s=5.0))
        inj = chaos.ChaosInjector(
            [chaos.Fault(chaos.STRAGGLER, at_call=0, count=1, period=1,
                         delay_s=0.3)], seed=103)
        inj.install(router.replicas()[0].server)
        try:
            tickets = _route_all(router, queries[:60], deadline_s=30.0)
            assert all(t.status == STATUS_OK for t in tickets), inj.describe()
            got = np.array([t.count for t in tickets], dtype=np.int32)
            np.testing.assert_array_equal(
                got, ref.overlap_counts_np(queries[:60], rects),
                err_msg=inj.describe())
            lat = sorted(t.latency_s for t in tickets)
            return lat[int(len(lat) * 0.99)], router.metrics()
        finally:
            router.stop()

    p99_plain, _ = run(hedge=False)
    p99_hedged, m = run(hedge=True)
    assert m["hedges"] > 0 and m["hedge_wins"] > 0
    assert p99_hedged < p99_plain, (p99_hedged, p99_plain)
    assert p99_hedged < 0.8 * p99_plain, (p99_hedged, p99_plain)


def test_hedge_pairs_same_layout_version(workload):
    """Hedges only pair replicas of the same layout version (the fence
    extends to duplicates): with no same-version partner, no hedge fires."""
    _, queries, tree, _, _ = workload
    router = _router(tree, hedge=True, hedge_delay_s=0.0)
    try:
        # make r1 a different version by hand: no valid hedge partner for r0
        router.replicas()[1].layout_version = "other-version"
        picked = router._pick(
            {"r0"}, version=router.replicas()[0].layout_version)
        assert picked is None
        tickets = _route_all(router, queries[:40], deadline_s=30.0)
        assert all(t.status == STATUS_OK for t in tickets)
        assert router.metrics()["hedges"] == 0    # fence blocked every hedge
    finally:
        router.stop()


# ----------------------------------------------------------- poisoned replica


def test_poisoned_replica_ejected(workload):
    """A replica returning in-bounds wrong answers (slips past the server's
    bounds sanity check) is caught by the router's sampled oracle
    cross-check, ejected, and its in-flight work fails over — every released
    response is exact."""
    rects, queries, tree, _, _ = workload
    router = _router(tree, crosscheck_every=1)
    rc = chaos.ReplicaChaos(
        [chaos.Fault(chaos.POISON, at_call=0, count=1, period=1)],
        seed=105).install(router.replicas()[0])
    try:
        tickets = _route_all(router, queries[:80], deadline_s=60.0)
        err = rc.describe()
        assert all(t.status == STATUS_OK for t in tickets), err
        got = np.array([t.count for t in tickets], dtype=np.int32)
        np.testing.assert_array_equal(
            got, ref.overlap_counts_np(queries[:80], rects), err_msg=err)
        m = router.metrics()
        assert m["ejections"] == 1, err
        assert router.replicas()[0].state == EJECTED
        assert all(t.replica == "r1" for t in tickets if t.attempts > 1)
    finally:
        router.stop()


# ------------------------------------------------------------ health routing


def test_probe_health_flap_and_recovery(workload):
    """Flapping probes move the EWMA health score down through min_health
    and back up once the fault clears; routing prefers the healthy replica
    while its peer is sick."""
    _, queries, tree, _, _ = workload
    router = _router(tree, min_health=0.5, health_alpha=0.5)
    r0 = router.replicas()[0]
    # crash every submit on r0 → probes fail while the fault is active
    rc = chaos.ReplicaChaos(
        [chaos.Fault(chaos.REPLICA_CRASH, at_call=0, count=4, period=0)],
        seed=107).install(r0)
    try:
        assert router.metrics()["replicas_healthy"] == 2
        first = router.probe()
        second = router.probe()
        assert first["r0"] is False and second["r0"] is False
        assert first["r1"] is True and second["r1"] is True
        assert r0.health_score < 0.5
        assert router.metrics()["replicas_healthy"] == 1
        # unhealthy replica is avoided while a healthy one exists
        assert router._pick(set()).name == "r1"
        # fault window over (4 submits consumed) → probes pass, score recovers
        for _ in range(4):
            router.probe()
        assert r0.health_score >= 0.5
        assert router.metrics()["replicas_healthy"] == 2
    finally:
        router.stop()
    text = router.prometheus_text()
    assert 'router_probe_failures_total{replica="r0"} 4' in text


def test_all_replicas_sick_still_routes(workload):
    """Health is a preference, not a gate: with every score below
    min_health the router still serves (degraded beats unavailable)."""
    _, queries, tree, _, _ = workload
    router = _router(tree)
    try:
        for r in router.replicas():
            r.health_score = 0.0
        tickets = _route_all(router, queries[:30])
        assert all(t.status == STATUS_OK for t in tickets)
        assert router.metrics()["replicas_healthy"] == 0
    finally:
        router.stop()


# ------------------------------------------------------- lifecycle and admin


def test_replica_state_fence_rejects_submit(workload):
    """DRAINING/RETIRED/EJECTED replicas refuse new work at the seam the
    router (and chaos wrappers) use."""
    _, _, tree, _, _ = workload
    router = _router(tree)
    try:
        rep = router.replicas()[0]
        rep.begin_drain()
        assert rep.state == DRAINING
        with pytest.raises(ReplicaUnavailableError):
            rep.submit(np.array([0, 0, 1, 1], np.int32), deadline_s=1.0)
    finally:
        router.stop()


def test_submit_validates_strictly(workload):
    _, _, tree, _, _ = workload
    router = _router(tree)
    try:
        with pytest.raises(QueryValidationError):
            router.submit(np.array([10, 10, 0, 0], np.int32))   # lo > hi
        with pytest.raises(QueryValidationError):
            router.submit(np.array([np.nan, 0.0, 1.0, 1.0]))
        with pytest.raises(QueryValidationError):
            router.submit(np.array([1, 2, 3], np.int32))
    finally:
        router.stop()


def test_stopped_router_fails_fast(workload):
    _, _, tree, _, _ = workload
    router = _router(tree)
    router.stop()
    t = router.submit(np.array([0, 0, 1, 1], np.int32))
    assert t.done and t.status == STATUS_FAILED and t.reason == "stopped"


def test_expired_deadline_fails_not_hangs(workload):
    """A routed request that cannot meet its deadline terminates as failed
    (deadline) — the router never leaves a ticket pending forever."""
    _, _, tree, _, _ = workload
    router = _router(tree)
    try:
        t = router.submit(np.array([0, 0, 1, 1], np.int32), deadline_s=0.0)
        assert t.wait(10.0)
        assert t.status == STATUS_FAILED and t.reason in (
            "deadline", "exhausted")
    finally:
        router.stop()


# -------------------------------------------------------------- observability


def test_aggregated_metrics_surface(workload):
    """One scrape surface: router series labeled by query kind, per-replica
    server series tagged replica=<name>, one HELP/TYPE block per metric."""
    _, queries, tree, _, _ = workload
    router = _router(tree)
    try:
        _route_all(router, queries[:64])
        text = router.prometheus_text()
    finally:
        router.stop()
    assert 'router_requests_total{query_kind="count"} 64' in text
    assert "router_replicas_healthy 2" in text
    assert 'router_replicas{state="active"} 2' in text
    assert 'serve_events_total{kind="served",replica="r0"}' in text
    assert 'serve_events_total{kind="served",replica="r1"}' in text
    assert 'serve_healthy{replica="r0"} 1' in text
    assert 'replica="r0"' in text and "_bucket" in text
    assert text.count("# TYPE serve_events_total counter") == 1
    assert text.count("# TYPE router_requests_total counter") == 1
    snap = router.snapshot()
    assert "router" in snap and set(snap["replicas"]) == {"r0", "r1"}
    assert "router_requests_total" in snap["router"]


def test_metrics_dict_shape(workload):
    _, queries, tree, _, _ = workload
    router = _router(tree)
    try:
        _route_all(router, queries[:32])
        m = router.metrics()
    finally:
        router.stop()
    assert m["responses_ok"] == 32 and m["responses_failed"] == 0
    assert m["requests"] == 32
    assert set(m["replicas"]) == {"r0", "r1"}
    assert all(s["state"] == ACTIVE for s in m["replicas"].values())
    assert m["request_p50_s"] is not None
    assert m["request_p50_s"] <= m["request_p99_s"]
