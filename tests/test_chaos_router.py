"""Chaos-router suite: the ISSUE's acceptance scenario.

A rolling layout swap under concurrent replica crash + straggler injection
must complete with zero dropped and zero duplicated responses, every
released count bit-equal to the single-replica reference for the layout
that served it.  Marked ``chaos_router`` and run in the dedicated CI job
(``timeout-minutes`` is the outer hang guard — the suite's own contract is
that no replica-level fault may hang the router).

Fault schedules are seed-derived (:func:`repro.testing.chaos.random_plan`)
or hand-written; either way every assertion carries
``ChaosInjector.describe()`` / ``ReplicaChaos.describe()`` so a failure
report names the seed and the exact plan to replay.
"""
import threading

import numpy as np
import pytest

from repro import compat
from repro.core import engine as beng
from repro.core import rtree
from repro.data import datasets, spider
from repro.kernels import ref
from repro.serve import router as router_mod
from repro.serve.router import RouterConfig, SpatialRouter, RETIRED
from repro.serve.spatial_serve import STATUS_OK, ServeConfig
from repro.testing import chaos

pytestmark = pytest.mark.chaos_router

SEED = 0xA11CE


@pytest.fixture(scope="module")
def workload():
    rects = spider.uniform(2500, seed=81, max_size=0.02)
    queries = datasets.make_queries(rects, 0.2, seed=82)   # 500 queries
    tree = rtree.build_str_3level(rects, leaf_capacity=32, fanout=8)
    rects2 = spider.uniform(2500, seed=83, max_size=0.02)
    tree2 = rtree.build_str_3level(rects2, leaf_capacity=32, fanout=8)
    return rects, queries, tree, rects2, tree2


def _factory(tree):
    def make():
        return beng.BroadcastEngine(tree, _mesh(), batch_size=64)
    return make


def _mesh():
    return compat.make_mesh((1, 1), ("data", "model"))


def test_rolling_swap_under_crash_and_straggler(workload):
    """Acceptance: 3 replicas serve live traffic while (a) one replica
    crashes on every submit, (b) another replica's device step straggles on
    a flapping schedule, and (c) the pool rolls to a new layout build — all
    at once.  Zero dropped responses, zero duplicated responses, zero
    failures, every count bit-equal to the reference of the layout that
    served it."""
    rects, queries, tree, rects2, tree2 = workload
    router = SpatialRouter(
        _factory(tree),
        config=RouterConfig(num_replicas=3, attempt_timeout_s=30.0,
                            failover_attempts=3),
        serve_config=ServeConfig(batch_size=64, watchdog_s=5.0,
                                 crosscheck_every=0))
    v1 = router.layout_version
    crash = chaos.ReplicaChaos(
        [chaos.Fault(chaos.REPLICA_CRASH, at_call=0, count=1, period=1)],
        seed=SEED).install(router.replicas()[0])
    straggle = chaos.ChaosInjector(
        [chaos.Fault(chaos.STRAGGLER, at_call=0, count=1, period=3,
                     delay_s=0.2)], seed=SEED)
    straggle.install(router.replicas()[1].server)
    err = lambda: f"{crash.describe()} + {straggle.describe()}"

    completions = []
    orig_complete = router_mod.RouterTicket._complete

    def counting_complete(self, **fields):
        won = orig_complete(self, **fields)
        if won:
            completions.append(self)
        return won

    tickets = []
    try:
        router_mod.RouterTicket._complete = counting_complete
        stop = threading.Event()

        def traffic():
            i = 0
            while not stop.is_set() and i < 300:
                tickets.append(
                    router.submit(queries[i % len(queries)],
                                  deadline_s=60.0))
                i += 1
                stop.wait(0.005)

        t = threading.Thread(target=traffic)
        t.start()
        try:
            router.swap_layout(_factory(tree2))   # rolls all three replicas
        finally:
            stop.set()
            t.join(60.0)
        assert all(tk.wait(120.0) for tk in tickets), err()
    finally:
        router_mod.RouterTicket._complete = orig_complete
        router.stop()

    assert tickets, "traffic thread never submitted"
    # zero dropped, zero failed
    bad = [(tk.status, tk.reason) for tk in tickets
           if tk.status != STATUS_OK]
    assert not bad, f"{bad[:5]} under {err()}"
    # zero duplicated: each ticket completed exactly once
    assert len(completions) == len(tickets), err()
    assert set(id(t) for t in completions) == set(id(t) for t in tickets)
    # bit-equal to the single-replica reference of the serving layout
    v2 = router.layout_version
    assert v2 != v1 and {tk.layout_version for tk in tickets} <= {v1, v2}
    rect_mat = np.stack([tk.rect for tk in tickets])
    w1 = ref.overlap_counts_np(rect_mat, rects)
    w2 = ref.overlap_counts_np(rect_mat, rects2)
    for i, tk in enumerate(tickets):
        want = int(w1[i] if tk.layout_version == v1 else w2[i])
        assert tk.count == want, (
            f"ticket {i} on {tk.layout_version}: {tk.count} != {want} "
            f"under {err()}")
    # the swap finished cleanly despite the chaos
    assert all(r.state == RETIRED for r in router._retired), err()
    assert all(r.layout_version == v2 for r in router.replicas()), err()
    m = router.metrics()
    assert m["responses_failed"] == 0, err()
    assert m["layout_swaps"] == 1


def test_seeded_plan_sweep_serves_exactly(workload):
    """Randomized-but-replayable: a seed-derived fault plan over both server
    seams never breaks exactness; the failure message carries the seed."""
    rects, queries, tree, _, _ = workload
    for seed in (7, 23):
        plan = chaos.random_plan(seed, n_faults=4, max_call=6,
                                 max_delay_s=0.05)
        router = SpatialRouter(
            _factory(tree),
            config=RouterConfig(num_replicas=2, attempt_timeout_s=30.0),
            serve_config=ServeConfig(batch_size=64, watchdog_s=5.0,
                                     max_retries=2, backoff_base_s=0.001,
                                     crosscheck_every=0))
        inj = chaos.ChaosInjector(plan, seed=seed)
        inj.install(router.replicas()[0].server)
        try:
            tickets = [router.submit(q, deadline_s=60.0)
                       for q in queries[:120]]
            assert all(t.wait(120.0) for t in tickets), inj.describe()
            assert all(t.status == STATUS_OK for t in tickets), inj.describe()
            got = np.array([t.count for t in tickets], dtype=np.int32)
            np.testing.assert_array_equal(
                got, ref.overlap_counts_np(queries[:120], rects),
                err_msg=inj.describe())
        finally:
            router.stop()


def test_hang_replica_covered_by_attempt_timeout(workload):
    """A wedged replica (accepts work, never answers) is covered by the
    per-attempt timeout: the router reroutes and every request completes."""
    rects, queries, tree, _, _ = workload
    router = SpatialRouter(
        _factory(tree),
        config=RouterConfig(num_replicas=2, attempt_timeout_s=0.3,
                            failover_attempts=3),
        serve_config=ServeConfig(batch_size=64, watchdog_s=5.0,
                                 crosscheck_every=0))
    rc = chaos.ReplicaChaos(
        [chaos.Fault(chaos.REPLICA_HANG, at_call=0, count=1, period=1)],
        seed=SEED).install(router.replicas()[0])
    try:
        tickets = [router.submit(q, deadline_s=30.0) for q in queries[:40]]
        assert all(t.wait(60.0) for t in tickets), rc.describe()
        assert all(t.status == STATUS_OK for t in tickets), rc.describe()
        got = np.array([t.count for t in tickets], dtype=np.int32)
        np.testing.assert_array_equal(
            got, ref.overlap_counts_np(queries[:40], rects),
            err_msg=rc.describe())
        assert router.metrics()["failovers"] > 0
    finally:
        router.stop()
