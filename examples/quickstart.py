"""Quickstart: the paper's pipeline in 40 lines.

Builds an STR R-tree over a synthetic SPIDER dataset, stands up the
Broadcast PIM engine on the active mesh, runs a batched range-query
workload, and cross-checks against the brute-force oracle.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax

from repro import compat

from repro.core import engine, rtree
from repro.data import datasets, spider
from repro.kernels import ref

# 1. data: 100K rectangles, 5% query workload (paper Table I pattern)
rects = spider.uniform(100_000, seed=0, max_size=0.001)
queries = datasets.make_queries(rects, 0.05)
print(f"{len(rects)} rects, {len(queries)} queries")

# 2. host-side STR bulk load, exactly three levels (paper Sec III-C.1)
mesh = compat.make_mesh((1, 1), ("data", "model"))
leaf_cap, fanout = rtree.choose_parameters(len(rects), mesh.size)
tree = rtree.build_str_3level(rects, leaf_cap, fanout)
print(f"R-tree: {tree.num_leaves} leaves (B={leaf_cap}), "
      f"{tree.num_l1} level-1 nodes (F={fanout})")

# 3. broadcast engine: headers replicated, leaves sharded, queries batched
eng = engine.BroadcastEngine(tree, mesh, batch_size=10_000)
counts = eng.query(queries)
print(f"total overlaps: {int(counts.sum())}")
print(f"comm model: {eng.transfer_stats(len(queries))}")

# 4. verify against the oracle
want = ref.overlap_counts_np(queries[:500], rects)
np.testing.assert_array_equal(counts[:500], want)
print("oracle cross-check: OK")
