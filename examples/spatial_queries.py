"""End-to-end driver for the paper's experiment matrix (scaled): all three
engines (CPU Algorithm 1, subtree baseline, broadcast) over two datasets ×
two query fractions, with agreement checks and the communication-volume
comparison that motivates the broadcast design (paper Table III / Fig 7).

    PYTHONPATH=src python examples/spatial_queries.py
"""
import time

import numpy as np

import jax

from repro import compat

from repro.core import cpu_baseline, engine, rtree, subtree
from repro.data import datasets
from repro.kernels import ref

mesh = compat.make_mesh((1, 1), ("data", "model"))

for name, n in (("sports", 50_000), ("lakes", 120_000)):
    rects = datasets.load(name, n=n)
    b, f = rtree.choose_parameters(n, 64)
    tree = rtree.build_str_3level(rects, b, f)
    b_eng = engine.BroadcastEngine(tree, mesh, batch_size=10_000)
    s_eng = subtree.SubtreeEngine(rects, mesh, leaf_capacity=max(b, 32),
                                  batch_size=10_000)
    for frac in (0.01, 0.05):
        queries = datasets.make_queries(rects, frac)
        t0 = time.perf_counter(); c_cpu = cpu_baseline.parallel_query(
            tree, queries); t_cpu = time.perf_counter() - t0
        t0 = time.perf_counter(); c_b = b_eng.query(queries)
        t_b = time.perf_counter() - t0
        t0 = time.perf_counter(); c_s = s_eng.query(queries)
        t_s = time.perf_counter() - t0
        assert (c_cpu == c_b).all() and (c_b == c_s).all()
        bl = engine.shard_tree(tree, 256)
        sl = subtree.build_layout(rects, 256, max(b, 32))
        nb = -(-len(queries) // 10_000)
        bcast = bl.header_bytes + bl.leaf_bytes + nb * 160_000
        sub = sl.scatter_bytes * nb + nb * 160_000
        print(f"{name} q={frac:.0%}: cpu {t_cpu:.2f}s | broadcast {t_b:.2f}s"
              f" | subtree {t_s:.2f}s | comm bytes broadcast/subtree = "
              f"{bcast / 1e6:.1f}MB / {sub / 1e6:.1f}MB "
              f"({sub / bcast:.1f}x)  [engines agree ✓]")
