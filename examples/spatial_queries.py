"""End-to-end driver for the paper's experiment matrix (scaled): all three
engines (CPU Algorithm 1, subtree baseline, broadcast) over two datasets ×
two query fractions, with agreement checks and the communication-volume
comparison that motivates the broadcast design (paper Table III / Fig 7) —
then the materializing query surface (DESIGN.md Sec 14): ID lists with
overflow accounting, kNN, radius, and on-fabric aggregates, each checked
against the NumPy oracle.

    PYTHONPATH=src python examples/spatial_queries.py
"""
import time

import numpy as np

import jax

from repro import compat

from repro.core import cpu_baseline, engine, rtree, subtree
from repro.data import datasets
from repro.kernels import ref
from repro.query import oracle

mesh = compat.make_mesh((1, 1), ("data", "model"))

for name, n in (("sports", 50_000), ("lakes", 120_000)):
    rects = datasets.load(name, n=n)
    b, f = rtree.choose_parameters(n, 64)
    tree = rtree.build_str_3level(rects, b, f)
    b_eng = engine.BroadcastEngine(tree, mesh, batch_size=10_000)
    s_eng = subtree.SubtreeEngine(rects, mesh, leaf_capacity=max(b, 32),
                                  batch_size=10_000)
    for frac in (0.01, 0.05):
        queries = datasets.make_queries(rects, frac)
        t0 = time.perf_counter(); c_cpu = cpu_baseline.parallel_query(
            tree, queries); t_cpu = time.perf_counter() - t0
        t0 = time.perf_counter(); c_b = b_eng.query(queries)
        t_b = time.perf_counter() - t0
        t0 = time.perf_counter(); c_s = s_eng.query(queries)
        t_s = time.perf_counter() - t0
        assert (c_cpu == c_b).all() and (c_b == c_s).all()
        bl = engine.shard_tree(tree, 256)
        sl = subtree.build_layout(rects, 256, max(b, 32))
        nb = -(-len(queries) // 10_000)
        bcast = bl.header_bytes + bl.leaf_bytes + nb * 160_000
        sub = sl.scatter_bytes * nb + nb * 160_000
        print(f"{name} q={frac:.0%}: cpu {t_cpu:.2f}s | broadcast {t_b:.2f}s"
              f" | subtree {t_s:.2f}s | comm bytes broadcast/subtree = "
              f"{bcast / 1e6:.1f}MB / {sub / 1e6:.1f}MB "
              f"({sub / bcast:.1f}x)  [engines agree ✓]")

# ---------------------------------------------------------------------------
# Materializing query surface (DESIGN.md Sec 14): same engines, four more
# kinds, every answer cross-checked against the NumPy oracle.
rects = datasets.load("sports", n=20_000)
b, f = rtree.choose_parameters(len(rects), 64)
b_eng = engine.BroadcastEngine(rtree.build_str_3level(rects, b, f), mesh,
                               batch_size=512)
queries = datasets.make_queries(rects, 0.02, seed=11)[:1024]
rng = np.random.default_rng(7)
points = rects[rng.integers(0, len(rects), 1024), :2].astype(np.int32)
radii = rng.integers(0, 40_000, 1024).astype(np.int32)
pr, pi = b_eng.placed_rects, b_eng.placed_ids

res = b_eng.query_ids(queries, kcap=64)
w_ids, w_cnt, w_ov = oracle.ids_oracle(queries, pr, pi, kcap=64)
assert (res.ids == w_ids).all() and (res.count == w_cnt).all()
print(f"ids: q0 matches {res.count[0]} rects -> {res.ids_for(0)[:6]}... | "
      f"{res.truncated.sum()} of {len(res)} queries truncated at kcap=64 "
      f"({res.total_overflow} ids dropped, accounted)  [oracle ✓]")

knn = b_eng.query_knn(points, k=8)
w_d, w_i = oracle.knn_oracle(points, pr, pi, k=8)
assert (knn.ids == w_i).all() and (knn.distances == w_d).all()
print(f"knn: p0 -> ids {knn.ids[0][:4]} d2 {knn.distances[0][:4]}  [oracle ✓]")

near = b_eng.query_radius(points, radii, kcap=64)
w_ids, w_cnt, _ = oracle.radius_oracle(points, radii, pr, pi, kcap=64)
assert (near.ids == w_ids).all() and (near.count == w_cnt).all()
print(f"radius: p0 within r={radii[0]} -> {near.count[0]} rects  [oracle ✓]")

agg = b_eng.query_aggregate(queries)
w_cnt, w_sums, w_bbox = oracle.aggregate_oracle(queries, pr)
assert (agg.count == w_cnt).all() and (agg.bbox == w_bbox).all()
np.testing.assert_allclose(agg.aggregates["sums"], w_sums,
                           rtol=oracle.AGG_RTOL, atol=oracle.AGG_ATOL)
print(f"aggregate: q0 count {agg.count[0]} centroid {agg.centroid[0]} "
      f"bbox {agg.bbox[0]}  [oracle ✓]")
