"""End-to-end training driver: train a ~100M-parameter llama-family model
for a few hundred steps on the synthetic corpus, with checkpointing and
auto-resume (kill it mid-run and re-run: it continues from the last step).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses

import jax

from repro import compat

from repro.configs import llama3_2_1b
from repro.train import train_loop

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
args = ap.parse_args()

# ~100M-parameter member of the llama3 family (assigned full config scaled
# to container hardware; the full config trains via the same entry point on
# a real mesh).
cfg = dataclasses.replace(
    llama3_2_1b.CONFIG, n_layers=4, d_model=512, n_heads=8, n_kv_heads=4,
    head_dim=64, d_ff=2048, vocab=32_000, arch_id="llama3-100m")

mesh = compat.make_mesh((1, 1), ("data", "model"))
res = train_loop.train(
    cfg, mesh, steps=args.steps, batch_size=8, seq_len=256,
    ckpt_dir=args.ckpt_dir, ckpt_every=100, lr=3e-4)
print(f"loss: {res['losses'][0]:.3f} → {res['losses'][-1]:.3f} "
      f"over {len(res['losses'])} steps")
