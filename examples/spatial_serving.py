"""Fault-tolerant serving quickstart (DESIGN.md Sec 11): build an engine,
start the server, submit requests, read the metrics surface, then inject a
fault plan and watch the server degrade to the reference kernel and recover.

    PYTHONPATH=src python examples/spatial_serving.py
"""
import numpy as np

from repro import compat
from repro.core import engine, rtree
from repro.data import datasets, spider
from repro.kernels import ref
from repro.serve.spatial_serve import ServeConfig, SpatialServer
from repro.testing import chaos

# --- build the engine exactly as in the offline examples -------------------
N = 20_000
rects = spider.uniform(N, seed=5)
tree = rtree.build_str_3level(rects, *rtree.choose_parameters(N, 1))
mesh = compat.make_mesh((1, 1), ("data", "model"))
eng = engine.BroadcastEngine(tree, mesh, batch_size=256)
queries = datasets.make_queries(rects, 0.05, seed=6)
want = ref.overlap_counts_np(queries, rects)

# --- healthy steady state --------------------------------------------------
srv = SpatialServer(eng, ServeConfig(batch_size=256))
srv.start()
tickets = [srv.submit(q, deadline_s=5.0) for q in queries]
assert all(t.wait(timeout=30.0) for t in tickets)
srv.stop()
got = np.array([t.count for t in tickets], dtype=np.int32)
np.testing.assert_array_equal(got, want)
m = srv.metrics()
print(f"clean: {m['served']} served on the {tickets[0].path!r} path, "
      f"health={m['health']}, "
      f"request p50={m['request_p50_s'] * 1e3:.1f}ms "
      f"p99={m['request_p99_s'] * 1e3:.1f}ms")

# --- same workload, hostile device -----------------------------------------
# Two transient device losses, then a persistent loss that exhausts retries:
# the server degrades to the NumPy reference kernel, keeps answering
# exactly, and the periodic probe re-arms the fast path once the fault
# schedule runs out.
srv = SpatialServer(eng, ServeConfig(batch_size=256, max_retries=1,
                                     backoff_base_s=0.005, probe_every=1))
chaos.ChaosInjector([
    chaos.Fault(chaos.DEVICE_LOSS, at_call=1, count=1),
    chaos.Fault(chaos.DEVICE_LOSS, at_call=3, count=2),
]).install(srv)
srv.start()
tickets = [srv.submit(q, deadline_s=30.0) for q in queries]
assert all(t.wait(timeout=60.0) for t in tickets)
srv.stop()
got = np.array([t.count for t in tickets], dtype=np.int32)
np.testing.assert_array_equal(got, want)      # exact under every fault
m = srv.metrics()
paths = {t.path for t in tickets}
print(f"chaos: {m['served']} served exactly via paths {sorted(paths)}; "
      f"retries={m['retries']} degradations={m['degradations']} "
      f"recoveries={m['recoveries']} faults={m['faults']} "
      f"final health={m['health']}")
