"""Serving example: batched greedy generation across four different
architecture families through one uniform decode API (KV caches, SSM states,
RG-LRU ring buffers all behind api.decode_step).

    PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np

import jax

from repro import configs
from repro.models import api
from repro.serve import serve_loop

rng = np.random.default_rng(0)
for arch in ("llama3.2-1b", "falcon-mamba-7b", "recurrentgemma-2b",
             "granite-moe-3b-a800m"):
    cfg = configs.get_config(arch, smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(1))
    prompts = rng.integers(3, cfg.vocab, (2, 8)).astype(np.int32)
    out = serve_loop.greedy_generate(cfg, params, prompts, num_steps=12,
                                     max_seq=64)
    print(f"{arch:24s} ({cfg.family:7s}): "
          f"prompt {prompts.shape[1]} → generated {out.shape[1] - 8} tokens"
          f"  e.g. {out[0, 8:14].tolist()}")
