"""Multi-replica serving quickstart (DESIGN.md Sec 13): a health-checked
router over shared-nothing replicas — failover when one crashes, hedged
retries against a straggler, and a zero-downtime rolling layout swap.

    PYTHONPATH=src python examples/multi_replica_serving.py [--out DIR]

``--out DIR`` persists the aggregated observability surface (one Prometheus
exposition with per-replica labels + the router metrics dict) — the CI
chaos-router job uploads that directory as an artifact.
"""
import argparse
import json
import os

import numpy as np

from repro import compat
from repro.core import engine, rtree
from repro.data import datasets, spider
from repro.kernels import ref
from repro.serve.router import RouterConfig, SpatialRouter
from repro.serve.spatial_serve import ServeConfig
from repro.testing import chaos

ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
ap.add_argument("--out", default=None,
                help="directory for metrics/prometheus artifacts")
args = ap.parse_args()

# --- one immutable layout per replica generation ---------------------------
N = 8_000
rects = spider.uniform(N, seed=5)
tree = rtree.build_str_3level(rects, *rtree.choose_parameters(N, 1))
queries = datasets.make_queries(rects, 0.05, seed=6)[:400]
want = ref.overlap_counts_np(queries, rects)
mesh = compat.make_mesh((1, 1), ("data", "model"))


def factory():
    """Each replica builds (and owns) its own placed engine — shared
    nothing, so one replica's device state can never poison another's."""
    return engine.BroadcastEngine(tree, mesh, batch_size=128)


serve_cfg = ServeConfig(batch_size=128, crosscheck_every=0)
router = SpatialRouter(
    factory,
    config=RouterConfig(num_replicas=2, attempt_timeout_s=5.0,
                        default_deadline_s=10.0, hedge=True,
                        hedge_delay_s=0.05, crosscheck_every=64),
    serve_config=serve_cfg)
print(f"pool up: layout {router.layout_version}, "
      f"replicas {[r.name for r in router.replicas()]}")

# --- healthy pool: routed answers are bit-equal to the offline engine ------
tickets = [router.submit(q, deadline_s=10.0) for q in queries]
assert all(t.wait(timeout=60.0) for t in tickets)
got = np.array([t.count for t in tickets], dtype=np.int32)
np.testing.assert_array_equal(got, want)
served_by = {t.replica for t in tickets}
print(f"clean: {len(tickets)} exact answers, load-balanced over "
      f"{sorted(served_by)}")

# --- crash one replica mid-stream: failover, zero lost requests ------------
crash = chaos.ReplicaChaos(
    [chaos.Fault(chaos.REPLICA_CRASH, at_call=0, count=1, period=1)],
    seed=7)
crash.install(router.replicas()[0])
tickets = [router.submit(q, deadline_s=10.0) for q in queries[:100]]
assert all(t.wait(timeout=60.0) for t in tickets)
got = np.array([t.count for t in tickets], dtype=np.int32)
np.testing.assert_array_equal(got, want[:100])
m = router.metrics()
print(f"crash: {crash.describe()}")
print(f"crash: 100/100 exact after {m['failovers']} failovers, "
      f"0 failed, healthy={m['replicas_healthy']}")

# --- rolling layout swap: new index build, zero dropped in-flight ----------
rects2 = spider.uniform(N, seed=8)
tree2 = rtree.build_str_3level(rects2, *rtree.choose_parameters(N, 1))
want2 = ref.overlap_counts_np(queries, rects2)
router.swap_layout(
    lambda: engine.BroadcastEngine(tree2, mesh, batch_size=128))
tickets = [router.submit(q, deadline_s=10.0) for q in queries[:100]]
assert all(t.wait(timeout=60.0) for t in tickets)
got = np.array([t.count for t in tickets], dtype=np.int32)
np.testing.assert_array_equal(got, want2[:100])
assert all(t.layout_version == router.layout_version for t in tickets)
print(f"swap: pool rolled to layout {router.layout_version}; every "
      f"post-swap answer exact on the new index "
      f"(retired: {[r.name for r in router._retired]})")

m = router.metrics()
print(f"final: requests={m['requests']} ok={m['responses_ok']} "
      f"failed={m['responses_failed']} hedges={m['hedges']} "
      f"hedge_wins={m['hedge_wins']} swaps={m['layout_swaps']}")

if args.out:
    os.makedirs(args.out, exist_ok=True)
    prom = os.path.join(args.out, "router_metrics.prom")
    with open(prom, "w") as fh:
        fh.write(router.prometheus_text())
    with open(os.path.join(args.out, "router_metrics.json"), "w") as fh:
        json.dump(m, fh, indent=2, default=float)
    print(f"wrote {prom} (+ router_metrics.json)")

router.stop()
