"""Repo-root pytest bootstrap.

Two jobs that must live at the rootdir:

* put ``src/`` on ``sys.path`` so the suite runs without an installed
  package (mirrors the documented ``PYTHONPATH=src`` invocation);
* register the pallint trace-guard plugin (``pytest_plugins`` is only
  honored in the rootdir conftest), exposing the shared
  ``pallint_steady_state`` / ``pallint_compile_count`` fixtures to every
  test.
"""
import os
import sys

_SRC = os.path.join(os.path.dirname(__file__), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

pytest_plugins = ("repro.analysis.pallint.pytest_plugin",)
